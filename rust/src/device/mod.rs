//! The simulated mobile-device substrate (see DESIGN.md §2 for the
//! substitution argument). Models the four SoCs of Table 1: heterogeneous
//! ARM big.LITTLE CPU clusters (Ruy-style equal-split multithreading,
//! cross-cluster sync overhead, int8 quantization effects) and mobile GPUs
//! (per-dispatch overhead, fusion, Winograd / grouped kernel selection),
//! plus a measurement-noise model reproducing the paper's variance findings
//! (Fig 32: CoV grows with core count, especially small-core clusters).

pub mod cost;
pub mod exec;
pub mod noise;
pub mod sample;
pub mod spec;

pub use exec::{run, OpTrace, RunTrace, Target};
pub use sample::{sample_specs, sample_workloads};
pub use spec::{
    builtin_specs, soc_from_json, soc_to_json, validate_soc, SocSpec, SPEC_FORMAT, SPEC_VERSION,
};

use crate::tflite::GpuKind;

/// Cluster tier within a big.LITTLE SoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ClusterKind {
    Large,
    Medium,
    Small,
}

impl ClusterKind {
    pub fn letter(&self) -> char {
        match self {
            ClusterKind::Large => 'L',
            ClusterKind::Medium => 'M',
            ClusterKind::Small => 'S',
        }
    }

    /// Stable name used by device-spec files.
    pub fn name(&self) -> &'static str {
        match self {
            ClusterKind::Large => "large",
            ClusterKind::Medium => "medium",
            ClusterKind::Small => "small",
        }
    }

    /// Inverse of [`name`](Self::name); also accepts the figure letters
    /// (`L`/`M`/`S`). Case-insensitive.
    pub fn parse(s: &str) -> Option<ClusterKind> {
        match s.to_ascii_lowercase().as_str() {
            "large" | "l" => Some(ClusterKind::Large),
            "medium" | "m" => Some(ClusterKind::Medium),
            "small" | "s" => Some(ClusterKind::Small),
            _ => None,
        }
    }
}

/// A homogeneous CPU core cluster sharing one clock domain.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreCluster {
    pub kind: ClusterKind,
    pub name: String,
    pub count: usize,
    pub ghz: f64,
    /// Peak fp32 FLOPs per cycle per core (NEON FMA width).
    pub flops_per_cycle: f64,
    /// int8 throughput multiplier vs fp32 (dot-product instructions).
    pub int8_speedup: f64,
    /// Effective per-core streaming bandwidth (GB/s) seen by Ruy-style
    /// kernels (packing + strided access make this far below DRAM peak;
    /// this term is what makes narrow architectures memory-bound).
    pub stream_gbps: f64,
}

impl CoreCluster {
    /// Peak fp32 GFLOPS of one core.
    pub fn peak_gflops(&self) -> f64 {
        self.ghz * self.flops_per_cycle
    }
}

/// A mobile GPU with TFLite-relevant performance parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub kind: GpuKind,
    pub name: String,
    /// Effective peak GFLOPS (fp16/fp32 mixed as TFLite GPU delegate uses).
    pub gflops: f64,
    /// Memory bandwidth available to the GPU (GB/s).
    pub mem_gbps: f64,
    /// Per-kernel dispatch overhead (µs): OpenCL enqueue + driver cost.
    pub dispatch_us: f64,
    /// Mean per-inference framework overhead (ms) — the Fig 10b gap.
    pub overhead_ms: f64,
    /// Log-std of the framework overhead (PowerVR/Mali are more variable).
    pub overhead_sigma: f64,
    /// Per-run multiplicative noise log-std (faster GPUs are noisier
    /// relative to their shorter run times — Section 5.5.2).
    pub run_sigma: f64,
}

/// A system-on-chip: CPU clusters (fastest first) + GPU. The paper's four
/// devices (Table 1) ship as committed spec files (see [`spec`]); any other
/// SoC is described the same way and registered at runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct Soc {
    pub name: String,
    pub platform: String,
    pub clusters: Vec<CoreCluster>,
    pub gpu: GpuSpec,
    /// CPU-side memory bandwidth (GB/s), shared across cores.
    pub mem_gbps: f64,
    /// Fixed per-op CPU dispatch overhead (µs).
    pub cpu_op_overhead_us: f64,
    /// Mean per-inference CPU framework overhead (ms) — the Fig 10a gap.
    pub cpu_overhead_ms: f64,
    /// Cross-cluster thread-sync penalty multiplier (Insight 1).
    pub hetero_sync_mult: f64,
    /// int8 rescale degradation factor for element-wise/pad ops (Insight 2).
    pub quant_ew_penalty: f64,
    /// Per-run noise: base log-std and per-small-core increment (Fig 32).
    pub noise_base: f64,
    pub noise_per_small_core: f64,
    pub noise_per_extra_core: f64,
}

/// Which cores an inference uses: cores per cluster index.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CoreCombo {
    /// `counts[i]` cores taken from `soc.clusters[i]`.
    pub counts: Vec<usize>,
}

impl CoreCombo {
    pub fn new(counts: Vec<usize>) -> CoreCombo {
        CoreCombo { counts }
    }

    pub fn total_cores(&self) -> usize {
        self.counts.iter().sum()
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.counts.iter().filter(|&&c| c > 0).count() > 1
    }

    /// Label like "1L+3M" for figures.
    pub fn label(&self, soc: &Soc) -> String {
        let mut parts = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                parts.push(format!("{}{}", c, soc.clusters[i].kind.letter()));
            }
        }
        parts.join("+")
    }

    /// Expand to a list of cluster indices, one per core.
    pub fn cores(&self) -> Vec<usize> {
        let mut v = Vec::with_capacity(self.total_cores());
        for (i, &c) in self.counts.iter().enumerate() {
            for _ in 0..c {
                v.push(i);
            }
        }
        v
    }

    /// Number of cores drawn from `Small` clusters.
    pub fn small_cores(&self, soc: &Soc) -> usize {
        self.counts
            .iter()
            .enumerate()
            .filter(|(i, _)| soc.clusters[*i].kind == ClusterKind::Small)
            .map(|(_, &c)| c)
            .sum()
    }

    pub fn validate(&self, soc: &Soc) -> Result<(), String> {
        if self.counts.len() != soc.clusters.len() {
            return Err(format!(
                "combo has {} clusters, {} has {}",
                self.counts.len(),
                soc.name,
                soc.clusters.len()
            ));
        }
        for (i, &c) in self.counts.iter().enumerate() {
            if c > soc.clusters[i].count {
                return Err(format!(
                    "combo wants {c} cores from cluster {} ({} available)",
                    soc.clusters[i].name, soc.clusters[i].count
                ));
            }
        }
        if self.total_cores() == 0 {
            return Err("combo has no cores".into());
        }
        Ok(())
    }
}

/// Data representation of weights and activations (Section 3.1.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataRep {
    Fp32,
    Int8,
}

impl DataRep {
    pub fn name(&self) -> &'static str {
        match self {
            DataRep::Fp32 => "fp32",
            DataRep::Int8 => "int8",
        }
    }

    /// Inverse of [`name`](Self::name), for bundle/spec descriptors.
    pub fn parse(s: &str) -> Option<DataRep> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" => Some(DataRep::Fp32),
            "int8" => Some(DataRep::Int8),
            _ => None,
        }
    }
    pub fn bytes(&self) -> f64 {
        match self {
            DataRep::Fp32 => 4.0,
            DataRep::Int8 => 1.0,
        }
    }
}

/// The four platforms of Table 1, built from the committed spec files
/// (`device/specs/*.json`) — the device table is data, not code. Compat
/// shim; the open-universe API is `scenario::Registry`.
pub fn socs() -> Vec<Soc> {
    builtin_specs().iter().map(|s| s.soc.clone()).collect()
}

/// Look up a builtin SoC by name. Compat shim over [`builtin_specs`];
/// runtime-registered devices live in a `scenario::Registry`.
pub fn soc_by_name(name: &str) -> Option<Soc> {
    builtin_specs().iter().find(|s| s.soc.name == name).map(|s| s.soc.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_socs_match_table1() {
        let s = socs();
        assert_eq!(s.len(), 4);
        let s855 = &s[0];
        assert_eq!(s855.clusters.len(), 3);
        assert_eq!(s855.clusters.iter().map(|c| c.count).sum::<usize>(), 8);
        assert_eq!(s855.gpu.name, "Adreno 640");
        let p35 = &s[3];
        assert_eq!(p35.clusters.len(), 2);
        assert_eq!(p35.gpu.kind, GpuKind::PowerVR);
    }

    #[test]
    fn combo_labels() {
        let s855 = soc_by_name("Snapdragon855").unwrap();
        let c = CoreCombo::new(vec![1, 3, 0]);
        assert_eq!(c.label(&s855), "1L+3M");
        assert!(c.is_heterogeneous());
        assert_eq!(c.total_cores(), 4);
        let c1 = CoreCombo::new(vec![0, 0, 2]);
        assert_eq!(c1.label(&s855), "2S");
        assert!(!c1.is_heterogeneous());
        assert_eq!(c1.small_cores(&s855), 2);
    }

    #[test]
    fn combo_validation() {
        let s855 = soc_by_name("Snapdragon855").unwrap();
        assert!(CoreCombo::new(vec![2, 0, 0]).validate(&s855).is_err()); // only 1 prime
        assert!(CoreCombo::new(vec![0, 0, 0]).validate(&s855).is_err());
        assert!(CoreCombo::new(vec![1, 0]).validate(&s855).is_err()); // wrong arity
        assert!(CoreCombo::new(vec![1, 3, 4]).validate(&s855).is_ok());
    }

    #[test]
    fn cluster_ordering_fast_first() {
        for soc in socs() {
            for w in soc.clusters.windows(2) {
                assert!(
                    w[0].peak_gflops() >= w[1].peak_gflops(),
                    "{}: clusters must be fastest-first",
                    soc.name
                );
            }
        }
    }

    #[test]
    fn large_cores_faster_than_small() {
        for soc in socs() {
            let first = soc.clusters.first().unwrap().peak_gflops();
            let last = soc.clusters.last().unwrap().peak_gflops();
            assert!(first > last, "{}", soc.name);
        }
    }
}
