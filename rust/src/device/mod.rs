//! The simulated mobile-device substrate (see DESIGN.md §2 for the
//! substitution argument). Models the four SoCs of Table 1: heterogeneous
//! ARM big.LITTLE CPU clusters (Ruy-style equal-split multithreading,
//! cross-cluster sync overhead, int8 quantization effects) and mobile GPUs
//! (per-dispatch overhead, fusion, Winograd / grouped kernel selection),
//! plus a measurement-noise model reproducing the paper's variance findings
//! (Fig 32: CoV grows with core count, especially small-core clusters).

pub mod cost;
pub mod exec;
pub mod noise;

pub use exec::{run, OpTrace, RunTrace, Target};

use crate::tflite::GpuKind;

/// Cluster tier within a big.LITTLE SoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ClusterKind {
    Large,
    Medium,
    Small,
}

impl ClusterKind {
    pub fn letter(&self) -> char {
        match self {
            ClusterKind::Large => 'L',
            ClusterKind::Medium => 'M',
            ClusterKind::Small => 'S',
        }
    }
}

/// A homogeneous CPU core cluster sharing one clock domain.
#[derive(Debug, Clone)]
pub struct CoreCluster {
    pub kind: ClusterKind,
    pub name: &'static str,
    pub count: usize,
    pub ghz: f64,
    /// Peak fp32 FLOPs per cycle per core (NEON FMA width).
    pub flops_per_cycle: f64,
    /// int8 throughput multiplier vs fp32 (dot-product instructions).
    pub int8_speedup: f64,
    /// Effective per-core streaming bandwidth (GB/s) seen by Ruy-style
    /// kernels (packing + strided access make this far below DRAM peak;
    /// this term is what makes narrow architectures memory-bound).
    pub stream_gbps: f64,
}

impl CoreCluster {
    /// Peak fp32 GFLOPS of one core.
    pub fn peak_gflops(&self) -> f64 {
        self.ghz * self.flops_per_cycle
    }
}

/// A mobile GPU with TFLite-relevant performance parameters.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub kind: GpuKind,
    pub name: &'static str,
    /// Effective peak GFLOPS (fp16/fp32 mixed as TFLite GPU delegate uses).
    pub gflops: f64,
    /// Memory bandwidth available to the GPU (GB/s).
    pub mem_gbps: f64,
    /// Per-kernel dispatch overhead (µs): OpenCL enqueue + driver cost.
    pub dispatch_us: f64,
    /// Mean per-inference framework overhead (ms) — the Fig 10b gap.
    pub overhead_ms: f64,
    /// Log-std of the framework overhead (PowerVR/Mali are more variable).
    pub overhead_sigma: f64,
    /// Per-run multiplicative noise log-std (faster GPUs are noisier
    /// relative to their shorter run times — Section 5.5.2).
    pub run_sigma: f64,
}

/// A system-on-chip: CPU clusters (fastest first) + GPU (Table 1).
#[derive(Debug, Clone)]
pub struct Soc {
    pub name: &'static str,
    pub platform: &'static str,
    pub clusters: Vec<CoreCluster>,
    pub gpu: GpuSpec,
    /// CPU-side memory bandwidth (GB/s), shared across cores.
    pub mem_gbps: f64,
    /// Fixed per-op CPU dispatch overhead (µs).
    pub cpu_op_overhead_us: f64,
    /// Mean per-inference CPU framework overhead (ms) — the Fig 10a gap.
    pub cpu_overhead_ms: f64,
    /// Cross-cluster thread-sync penalty multiplier (Insight 1).
    pub hetero_sync_mult: f64,
    /// int8 rescale degradation factor for element-wise/pad ops (Insight 2).
    pub quant_ew_penalty: f64,
    /// Per-run noise: base log-std and per-small-core increment (Fig 32).
    pub noise_base: f64,
    pub noise_per_small_core: f64,
    pub noise_per_extra_core: f64,
}

/// Which cores an inference uses: cores per cluster index.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CoreCombo {
    /// `counts[i]` cores taken from `soc.clusters[i]`.
    pub counts: Vec<usize>,
}

impl CoreCombo {
    pub fn new(counts: Vec<usize>) -> CoreCombo {
        CoreCombo { counts }
    }

    pub fn total_cores(&self) -> usize {
        self.counts.iter().sum()
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.counts.iter().filter(|&&c| c > 0).count() > 1
    }

    /// Label like "1L+3M" for figures.
    pub fn label(&self, soc: &Soc) -> String {
        let mut parts = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                parts.push(format!("{}{}", c, soc.clusters[i].kind.letter()));
            }
        }
        parts.join("+")
    }

    /// Expand to a list of cluster indices, one per core.
    pub fn cores(&self) -> Vec<usize> {
        let mut v = Vec::with_capacity(self.total_cores());
        for (i, &c) in self.counts.iter().enumerate() {
            for _ in 0..c {
                v.push(i);
            }
        }
        v
    }

    /// Number of cores drawn from `Small` clusters.
    pub fn small_cores(&self, soc: &Soc) -> usize {
        self.counts
            .iter()
            .enumerate()
            .filter(|(i, _)| soc.clusters[*i].kind == ClusterKind::Small)
            .map(|(_, &c)| c)
            .sum()
    }

    pub fn validate(&self, soc: &Soc) -> Result<(), String> {
        if self.counts.len() != soc.clusters.len() {
            return Err(format!(
                "combo has {} clusters, {} has {}",
                self.counts.len(),
                soc.name,
                soc.clusters.len()
            ));
        }
        for (i, &c) in self.counts.iter().enumerate() {
            if c > soc.clusters[i].count {
                return Err(format!(
                    "combo wants {c} cores from cluster {} ({} available)",
                    soc.clusters[i].name, soc.clusters[i].count
                ));
            }
        }
        if self.total_cores() == 0 {
            return Err("combo has no cores".into());
        }
        Ok(())
    }
}

/// Data representation of weights and activations (Section 3.1.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataRep {
    Fp32,
    Int8,
}

impl DataRep {
    pub fn name(&self) -> &'static str {
        match self {
            DataRep::Fp32 => "fp32",
            DataRep::Int8 => "int8",
        }
    }
    pub fn bytes(&self) -> f64 {
        match self {
            DataRep::Fp32 => 4.0,
            DataRep::Int8 => 1.0,
        }
    }
}

/// The four platforms of Table 1.
pub fn socs() -> Vec<Soc> {
    vec![
        // Google Pixel 4 — Snapdragon 855: 1x Kryo 485 Prime 2.84 GHz,
        // 3x Kryo 485 Gold 2.42 GHz, 4x Kryo 485 Silver 1.80 GHz; Adreno 640.
        Soc {
            name: "Snapdragon855",
            platform: "Google Pixel 4",
            clusters: vec![
                CoreCluster { kind: ClusterKind::Large, name: "Kryo 485 Prime", count: 1, ghz: 2.84, flops_per_cycle: 16.0, int8_speedup: 3.0, stream_gbps: 8.50 },
                CoreCluster { kind: ClusterKind::Medium, name: "Kryo 485 Gold", count: 3, ghz: 2.42, flops_per_cycle: 16.0, int8_speedup: 3.0, stream_gbps: 7.00 },
                CoreCluster { kind: ClusterKind::Small, name: "Kryo 485 Silver", count: 4, ghz: 1.80, flops_per_cycle: 8.0, int8_speedup: 2.4, stream_gbps: 4.00 },
            ],
            gpu: GpuSpec {
                kind: GpuKind::Adreno6xx,
                name: "Adreno 640",
                gflops: 900.0,
                mem_gbps: 28.0,
                dispatch_us: 28.0,
                overhead_ms: 3.2,
                overhead_sigma: 0.10,
                run_sigma: 0.035,
            },
            mem_gbps: 28.0,
            cpu_op_overhead_us: 3.0,
            cpu_overhead_ms: 0.7,
            hetero_sync_mult: 2.6,
            quant_ew_penalty: 2.55,
            noise_base: 0.012,
            noise_per_small_core: 0.016,
            noise_per_extra_core: 0.006,
        },
        // Xiaomi Mi 8 SE — Snapdragon 710: 2x Kryo 360 Gold 2.2 GHz,
        // 6x Kryo 360 Silver 1.7 GHz; Adreno 616.
        Soc {
            name: "Snapdragon710",
            platform: "Xiaomi Mi 8 SE",
            clusters: vec![
                CoreCluster { kind: ClusterKind::Large, name: "Kryo 360 Gold", count: 2, ghz: 2.2, flops_per_cycle: 16.0, int8_speedup: 2.6, stream_gbps: 6.50 },
                CoreCluster { kind: ClusterKind::Small, name: "Kryo 360 Silver", count: 6, ghz: 1.7, flops_per_cycle: 8.0, int8_speedup: 2.2, stream_gbps: 3.50 },
            ],
            gpu: GpuSpec {
                kind: GpuKind::Adreno6xx,
                name: "Adreno 616",
                gflops: 380.0,
                mem_gbps: 13.0,
                dispatch_us: 34.0,
                overhead_ms: 4.1,
                overhead_sigma: 0.08,
                run_sigma: 0.022,
            },
            mem_gbps: 13.0,
            cpu_op_overhead_us: 4.0,
            cpu_overhead_ms: 0.9,
            hetero_sync_mult: 2.4,
            quant_ew_penalty: 2.35,
            noise_base: 0.012,
            noise_per_small_core: 0.013,
            noise_per_extra_core: 0.005,
        },
        // Samsung Galaxy S10 — Exynos 9820: 2x M4 2.73 GHz, 2x A75 2.31 GHz,
        // 4x A55 1.95 GHz; Mali G76.
        Soc {
            name: "Exynos9820",
            platform: "Samsung Galaxy S10",
            clusters: vec![
                CoreCluster { kind: ClusterKind::Large, name: "M4 Cheetah", count: 2, ghz: 2.73, flops_per_cycle: 24.0, int8_speedup: 2.8, stream_gbps: 9.00 },
                CoreCluster { kind: ClusterKind::Medium, name: "Cortex-A75", count: 2, ghz: 2.31, flops_per_cycle: 16.0, int8_speedup: 2.8, stream_gbps: 6.50 },
                CoreCluster { kind: ClusterKind::Small, name: "Cortex-A55", count: 4, ghz: 1.95, flops_per_cycle: 8.0, int8_speedup: 2.3, stream_gbps: 3.75 },
            ],
            gpu: GpuSpec {
                kind: GpuKind::Mali,
                name: "Mali G76",
                gflops: 780.0,
                mem_gbps: 28.0,
                dispatch_us: 42.0,
                overhead_ms: 5.6,
                overhead_sigma: 0.18,
                run_sigma: 0.045,
            },
            mem_gbps: 28.0,
            cpu_op_overhead_us: 3.2,
            cpu_overhead_ms: 0.8,
            // Exynos inter-cluster communication is notoriously costly
            // (Section 5.2: hetero combos show the worst variability here).
            hetero_sync_mult: 3.4,
            quant_ew_penalty: 2.60,
            noise_base: 0.014,
            noise_per_small_core: 0.022,
            noise_per_extra_core: 0.008,
        },
        // Samsung Galaxy A03s — Helio P35: 4x A53 2.3 GHz + 4x A53 1.8 GHz;
        // PowerVR GE8320. Both clusters are Cortex-A53 (Section 5.5.2).
        Soc {
            name: "HelioP35",
            platform: "Samsung Galaxy A03s",
            clusters: vec![
                CoreCluster { kind: ClusterKind::Large, name: "Cortex-A53 @2.3", count: 4, ghz: 2.3, flops_per_cycle: 8.0, int8_speedup: 1.9, stream_gbps: 4.00 },
                CoreCluster { kind: ClusterKind::Small, name: "Cortex-A53 @1.8", count: 4, ghz: 1.8, flops_per_cycle: 8.0, int8_speedup: 1.9, stream_gbps: 3.25 },
            ],
            gpu: GpuSpec {
                kind: GpuKind::PowerVR,
                name: "PowerVR GE8320",
                gflops: 55.0,
                mem_gbps: 6.5,
                dispatch_us: 60.0,
                overhead_ms: 7.5,
                overhead_sigma: 0.20,
                run_sigma: 0.016,
            },
            mem_gbps: 6.5,
            cpu_op_overhead_us: 7.0,
            cpu_overhead_ms: 1.4,
            // Same microarchitecture in both clusters: cheap migration.
            hetero_sync_mult: 1.6,
            quant_ew_penalty: 2.2,
            noise_base: 0.012,
            noise_per_small_core: 0.012,
            noise_per_extra_core: 0.006,
        },
    ]
}

/// Look up a SoC by name.
pub fn soc_by_name(name: &str) -> Option<Soc> {
    socs().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_socs_match_table1() {
        let s = socs();
        assert_eq!(s.len(), 4);
        let s855 = &s[0];
        assert_eq!(s855.clusters.len(), 3);
        assert_eq!(s855.clusters.iter().map(|c| c.count).sum::<usize>(), 8);
        assert_eq!(s855.gpu.name, "Adreno 640");
        let p35 = &s[3];
        assert_eq!(p35.clusters.len(), 2);
        assert_eq!(p35.gpu.kind, GpuKind::PowerVR);
    }

    #[test]
    fn combo_labels() {
        let s855 = soc_by_name("Snapdragon855").unwrap();
        let c = CoreCombo::new(vec![1, 3, 0]);
        assert_eq!(c.label(&s855), "1L+3M");
        assert!(c.is_heterogeneous());
        assert_eq!(c.total_cores(), 4);
        let c1 = CoreCombo::new(vec![0, 0, 2]);
        assert_eq!(c1.label(&s855), "2S");
        assert!(!c1.is_heterogeneous());
        assert_eq!(c1.small_cores(&s855), 2);
    }

    #[test]
    fn combo_validation() {
        let s855 = soc_by_name("Snapdragon855").unwrap();
        assert!(CoreCombo::new(vec![2, 0, 0]).validate(&s855).is_err()); // only 1 prime
        assert!(CoreCombo::new(vec![0, 0, 0]).validate(&s855).is_err());
        assert!(CoreCombo::new(vec![1, 0]).validate(&s855).is_err()); // wrong arity
        assert!(CoreCombo::new(vec![1, 3, 4]).validate(&s855).is_ok());
    }

    #[test]
    fn cluster_ordering_fast_first() {
        for soc in socs() {
            for w in soc.clusters.windows(2) {
                assert!(
                    w[0].peak_gflops() >= w[1].peak_gflops(),
                    "{}: clusters must be fastest-first",
                    soc.name
                );
            }
        }
    }

    #[test]
    fn large_cores_faster_than_small() {
        for soc in socs() {
            let first = soc.clusters.first().unwrap().peak_gflops();
            let last = soc.clusters.last().unwrap().peak_gflops();
            assert!(first > last, "{}", soc.name);
        }
    }
}
