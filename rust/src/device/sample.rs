//! Seed-deterministic sampling of schema-valid synthetic [`SocSpec`]s — the
//! fleet-scale device universe behind `edgelat bench`'s fleet stage.
//!
//! The open device universe (PR 5) made SoCs pure data; this module makes
//! that universe *large*: hundreds of random but physically plausible SoCs,
//! each passing [`SocSpec::validate`] by construction, so the vectorized
//! predictor kernels can be exercised far beyond the paper's four devices.
//!
//! Validity is structural, not retried: cluster tiers are drawn distinct and
//! fastest-first (all clusters share one `flops_per_cycle` while the `ghz`
//! chain strictly descends, so `peak_gflops` strictly descends as
//! `validate_soc` requires), every rate parameter comes from a positive
//! range, penalty multipliers start at 1, and combos are deduplicated by
//! count vector (distinct count vectors over distinct tiers give distinct
//! scenario labels). Sampling is keyed per SoC from `(seed, index)`, so any
//! prefix of the fleet is stable as `n` grows.

use crate::device::{ClusterKind, CoreCluster, GpuSpec, Soc, SocSpec};
use crate::tflite::GpuKind;
use crate::util::Rng;
use crate::workload::WorkloadSpec;

/// Domain-separation label for the fleet-sampling stream ("SoCS").
const STREAM: u64 = 0x50c5;
/// Domain-separation label for the workload-sampling stream — its own
/// stream, so adding workload draws never perturbs the SoC fleet (the
/// seed-prefix stability tests pin the SoC stream).
const WL_STREAM: u64 = 0x301d;

/// Sample `n` schema-valid synthetic SoC specs. Deterministic in `seed`,
/// and spec `i` depends only on `(seed, i)` — growing `n` never perturbs
/// earlier specs.
pub fn sample_specs(seed: u64, n: usize) -> Vec<SocSpec> {
    (0..n).map(|i| sample_spec(seed, i)).collect()
}

fn sample_spec(seed: u64, i: usize) -> SocSpec {
    let mut rng = Rng::derive(seed, &[STREAM, i as u64]);
    let name = format!("FleetSoc{seed:x}n{i}");

    // 1..=3 distinct cluster tiers, fastest first.
    let k = rng.range_usize(1, 3);
    let kinds = [ClusterKind::Large, ClusterKind::Medium, ClusterKind::Small];
    let flops_per_cycle = *rng.choice(&[4.0, 8.0, 16.0]);
    let mut ghz = rng.range_f64(1.6, 3.2);
    let mut clusters = Vec::with_capacity(k);
    for kind in &kinds[..k] {
        clusters.push(CoreCluster {
            kind: *kind,
            name: format!("{name}-{}", kind.name()),
            count: rng.range_usize(1, 8),
            ghz,
            flops_per_cycle,
            int8_speedup: rng.range_f64(1.2, 3.0),
            stream_gbps: rng.range_f64(2.0, 12.0),
        });
        // Strictly shrink the clock for the next (slower) tier.
        ghz *= rng.range_f64(0.5, 0.95);
    }

    let gpu_kinds =
        [GpuKind::Adreno6xx, GpuKind::Adreno, GpuKind::Mali, GpuKind::PowerVR, GpuKind::Amd];
    let gpu = GpuSpec {
        kind: *rng.choice(&gpu_kinds),
        name: format!("{name}-gpu"),
        gflops: rng.range_f64(100.0, 1200.0),
        mem_gbps: rng.range_f64(10.0, 40.0),
        dispatch_us: rng.range_f64(10.0, 80.0),
        overhead_ms: rng.range_f64(0.3, 4.0),
        overhead_sigma: rng.range_f64(0.05, 0.5),
        run_sigma: rng.range_f64(0.01, 0.10),
    };

    let soc = Soc {
        name,
        platform: "synthetic".to_string(),
        clusters,
        gpu,
        mem_gbps: rng.range_f64(8.0, 40.0),
        cpu_op_overhead_us: rng.range_f64(5.0, 40.0),
        cpu_overhead_ms: rng.range_f64(0.2, 2.0),
        hetero_sync_mult: rng.range_f64(1.0, 1.6),
        quant_ew_penalty: rng.range_f64(1.0, 2.5),
        noise_base: rng.range_f64(0.005, 0.05),
        noise_per_small_core: rng.range_f64(0.0, 0.01),
        noise_per_extra_core: rng.range_f64(0.0, 0.005),
    };

    // Studied combos: the single-fast-core headline combo, the all-cores
    // combo, plus up to two random draws — deduplicated by count vector.
    let counts: Vec<usize> = soc.clusters.iter().map(|c| c.count).collect();
    let mut one = vec![0usize; counts.len()];
    one[0] = 1;
    let mut combos = vec![one];
    if !combos.contains(&counts) {
        combos.push(counts.clone());
    }
    for _ in 0..2 {
        let mut c: Vec<usize> = counts.iter().map(|&max| rng.range_usize(0, max)).collect();
        if c.iter().sum::<usize>() == 0 {
            c[0] = 1;
        }
        if !combos.contains(&c) {
            combos.push(c);
        }
    }

    let spec = SocSpec::new(soc, combos);
    if let Err(e) = spec.validate() {
        panic!("sampled spec failed validation (sampler bug): {e}");
    }
    spec
}

/// Sample `n` schema-valid workload specs — the contention/batch analogue
/// of [`sample_specs`], so the fleet bench exercises the workload axes
/// beyond the committed presets. Same determinism contract: workload `i`
/// depends only on `(seed, i)`, on a stream separate from the SoC
/// sampler's, so interleaving the two never changes either sequence.
pub fn sample_workloads(seed: u64, n: usize) -> Vec<WorkloadSpec> {
    (0..n).map(|i| sample_workload(seed, i)).collect()
}

fn sample_workload(seed: u64, i: usize) -> WorkloadSpec {
    let mut rng = Rng::derive(seed, &[WL_STREAM, i as u64]);
    let wl = WorkloadSpec {
        name: format!("FleetWl{seed:x}n{i}"),
        // Powers of two 1..=8: the batch range the scenario universe
        // sweeps (deeper batching belongs to explicit spec files).
        batch: 1 << rng.range_usize(0, 3),
        // Up to 3 per-cluster loads; the last entry broadcasts on SoCs
        // with more clusters.
        cpu_load: (0..rng.range_usize(1, 3)).map(|_| rng.range_f64(0.0, 1.0)).collect(),
        gpu_share: rng.range_f64(0.25, 1.0),
    };
    if let Err(e) = wl.validate() {
        panic!("sampled workload failed validation (sampler bug): {e}");
    }
    wl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Registry;

    #[test]
    fn sampling_is_seed_deterministic() {
        assert_eq!(sample_specs(7, 24), sample_specs(7, 24));
        // Prefix stability: spec i depends only on (seed, i).
        assert_eq!(sample_specs(7, 24)[..8], sample_specs(7, 8)[..]);
        assert_ne!(sample_specs(1, 8), sample_specs(2, 8));
    }

    #[test]
    fn sampled_specs_validate_register_and_roundtrip() {
        let specs = sample_specs(2022, 120);
        assert_eq!(specs.len(), 120);
        let mut reg = Registry::new();
        let mut scenarios = 0;
        for s in &specs {
            s.validate().unwrap();
            scenarios += s.scenario_count();
            reg.register_soc(s.clone()).unwrap();
            // Round-trips through the spec schema like a hand-written file.
            let parsed =
                SocSpec::from_json(&crate::util::Json::parse(&s.to_json().to_string()).unwrap())
                    .unwrap();
            assert_eq!(&parsed, s);
        }
        assert_eq!(reg.soc_count(), 120);
        assert_eq!(reg.scenario_count(), scenarios);
        assert!(scenarios >= 120 * 3, "each spec yields at least 1 combo x 2 reps + gpu");
    }

    #[test]
    fn workload_sampler_is_deterministic_and_leaves_the_soc_stream_alone() {
        assert_eq!(sample_workloads(7, 16), sample_workloads(7, 16));
        assert_eq!(sample_workloads(7, 16)[..5], sample_workloads(7, 5)[..]);
        assert_ne!(sample_workloads(1, 5), sample_workloads(2, 5));
        for wl in sample_workloads(2022, 64) {
            wl.validate().unwrap();
        }
        // Coverage of both axes across a modest draw.
        let wls = sample_workloads(5, 64);
        assert!(wls.iter().any(|w| w.batch > 1));
        assert!(wls.iter().any(|w| w.batch == 1));
        assert!(wls.iter().any(|w| w.gpu_share < 0.9));
        assert!(wls.iter().any(|w| w.cpu_load.len() > 1));
        // Its own RNG stream: the SoC fleet is byte-identical whether or
        // not workloads were drawn from the same seed.
        let before = sample_specs(9, 12);
        let _ = sample_workloads(9, 12);
        assert_eq!(before, sample_specs(9, 12));
    }

    #[test]
    fn sampler_covers_the_space() {
        let specs = sample_specs(5, 64);
        let tiers: std::collections::BTreeSet<usize> =
            specs.iter().map(|s| s.soc.clusters.len()).collect();
        assert_eq!(tiers.into_iter().collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(specs.iter().any(|s| s.combos.len() > 2), "random extra combos appear");
        assert!(specs.iter().any(|s| s.soc.clusters.iter().any(|c| c.count > 4)));
    }
}
