//! Measurement-noise model.
//!
//! Real measurements in the paper fluctuate with background jobs (camera,
//! sensors, networking), DVFS and inter-cluster migration; the coefficient
//! of variation grows with the number of cores used — especially small
//! ("efficiency") cores, which share the cluster with background work
//! (Fig 32, Sections 5.2/5.5.2). We model:
//!
//! - a per-run correlated log-normal factor (whole-inference slowdown),
//!   whose log-std grows with core count and small-core count;
//! - per-op i.i.d. log-normal jitter;
//! - rare heavy-tail outliers (a background job stealing the cluster).

use crate::device::{CoreCombo, Soc};
use crate::util::Rng;
use crate::workload::WorkloadSpec;

#[derive(Debug, Clone, Copy)]
pub struct NoiseParams {
    /// Log-std of the per-run correlated factor.
    pub run_sigma: f64,
    /// Log-std of per-op jitter.
    pub op_sigma: f64,
    /// Probability that a run is an outlier.
    pub outlier_p: f64,
    /// Outlier multiplier range.
    pub outlier_lo: f64,
    pub outlier_hi: f64,
}

/// Noise parameters for a CPU scenario.
pub fn cpu_noise(soc: &Soc, combo: &CoreCombo) -> NoiseParams {
    let n = combo.total_cores();
    let small = combo.small_cores(soc);
    let hetero_extra = if combo.is_heterogeneous() { 0.008 } else { 0.0 };
    let run_sigma = soc.noise_base
        + soc.noise_per_small_core * small as f64
        + soc.noise_per_extra_core * (n - 1) as f64
        + hetero_extra;
    // Using the whole small cluster maximizes contention with background
    // jobs (the paper's worst cases: 6 small on S710, 4 small on E9820).
    let all_small = small > 0 && small == soc.clusters.iter().filter(|c| c.kind == crate::device::ClusterKind::Small).map(|c| c.count).sum::<usize>();
    let outlier_p = if all_small {
        0.035
    } else if small > 0 {
        0.02
    } else {
        0.01
    };
    NoiseParams {
        run_sigma,
        op_sigma: 0.025,
        outlier_p,
        outlier_lo: 1.4,
        outlier_hi: 3.2,
    }
}

/// Noise parameters for a GPU scenario.
pub fn gpu_noise(soc: &Soc) -> NoiseParams {
    NoiseParams {
        run_sigma: soc.gpu.run_sigma,
        op_sigma: 0.02,
        outlier_p: 0.008,
        outlier_lo: 1.3,
        outlier_hi: 2.2,
    }
}

/// [`cpu_noise`] under an optional workload. Co-runners are exactly the
/// "background jobs" the base model attributes its variance to, so load
/// adds run-to-run spread and outlier mass on top of the isolated
/// parameters; `None` returns them untouched (bit-identical traces).
pub fn cpu_noise_under(soc: &Soc, combo: &CoreCombo, wl: Option<&WorkloadSpec>) -> NoiseParams {
    let p = cpu_noise(soc, combo);
    let Some(wl) = wl else { return p };
    let load = wl.combo_load(combo);
    NoiseParams {
        run_sigma: p.run_sigma + 0.012 * load,
        outlier_p: (p.outlier_p * (1.0 + 1.5 * load)).min(0.25),
        ..p
    }
}

/// [`gpu_noise`] under an optional workload: a shrinking quota share means
/// more preemption points, hence more run-to-run spread and outlier mass.
pub fn gpu_noise_under(soc: &Soc, wl: Option<&WorkloadSpec>) -> NoiseParams {
    let p = gpu_noise(soc);
    let Some(wl) = wl else { return p };
    let stolen = 1.0 - wl.gpu_share;
    NoiseParams {
        run_sigma: p.run_sigma + 0.01 * stolen,
        outlier_p: (p.outlier_p * (1.0 + stolen)).min(0.25),
        ..p
    }
}

/// Per-run sampled factors.
#[derive(Debug, Clone, Copy)]
pub struct RunNoise {
    /// Correlated multiplier applied to every op this run.
    pub run_factor: f64,
    pub op_sigma: f64,
}

impl NoiseParams {
    /// Draw this run's correlated factor (including possible outlier).
    pub fn sample_run(&self, rng: &mut Rng) -> RunNoise {
        let mut f = rng.lognormal_unit_mean(self.run_sigma);
        if rng.bool(self.outlier_p) {
            f *= rng.range_f64(self.outlier_lo, self.outlier_hi);
        }
        RunNoise { run_factor: f, op_sigma: self.op_sigma }
    }
}

impl RunNoise {
    /// Apply per-op jitter on top of the run factor.
    pub fn op_factor(&self, rng: &mut Rng) -> f64 {
        self.run_factor * rng.lognormal_unit_mean(self.op_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::soc_by_name;

    #[test]
    fn more_cores_noisier() {
        let soc = soc_by_name("Snapdragon710").unwrap();
        let one = cpu_noise(&soc, &CoreCombo::new(vec![0, 1]));
        let six = cpu_noise(&soc, &CoreCombo::new(vec![0, 6]));
        assert!(six.run_sigma > 2.0 * one.run_sigma);
        assert!(six.outlier_p > one.outlier_p);
    }

    #[test]
    fn small_cores_noisier_than_large() {
        let soc = soc_by_name("Exynos9820").unwrap();
        let large2 = cpu_noise(&soc, &CoreCombo::new(vec![2, 0, 0]));
        let small2 = cpu_noise(&soc, &CoreCombo::new(vec![0, 0, 2]));
        assert!(small2.run_sigma > large2.run_sigma);
    }

    #[test]
    fn fast_gpus_relatively_noisier() {
        // Section 5.5.2: slower GPUs show smaller relative variance.
        let mali = gpu_noise(&soc_by_name("Exynos9820").unwrap());
        let powervr = gpu_noise(&soc_by_name("HelioP35").unwrap());
        assert!(mali.run_sigma > powervr.run_sigma);
    }

    #[test]
    fn noise_is_unit_mean() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let p = cpu_noise(&soc, &CoreCombo::new(vec![1, 0, 0]));
        let mut rng = Rng::new(7);
        let n = 40_000;
        let mean: f64 =
            (0..n).map(|_| p.sample_run(&mut rng).run_factor).sum::<f64>() / n as f64;
        // Outliers push the mean slightly above 1.
        assert!((0.98..1.06).contains(&mean), "mean={mean}");
    }

    #[test]
    fn workload_none_leaves_noise_untouched() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 2, 0]);
        let base = cpu_noise(&soc, &combo);
        let under = cpu_noise_under(&soc, &combo, None);
        assert_eq!(base.run_sigma, under.run_sigma);
        assert_eq!(base.outlier_p, under.outlier_p);
        let g = gpu_noise(&soc);
        let gu = gpu_noise_under(&soc, None);
        assert_eq!(g.run_sigma, gu.run_sigma);
        assert_eq!(g.outlier_p, gu.outlier_p);
    }

    #[test]
    fn contended_runs_are_noisier() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 0, 0]);
        let wl = WorkloadSpec { name: "w".into(), batch: 1, cpu_load: vec![0.8], gpu_share: 0.5 };
        let base = cpu_noise(&soc, &combo);
        let under = cpu_noise_under(&soc, &combo, Some(&wl));
        assert!(under.run_sigma > base.run_sigma);
        assert!(under.outlier_p > base.outlier_p);
        let g = gpu_noise(&soc);
        let gu = gpu_noise_under(&soc, Some(&wl));
        assert!(gu.run_sigma > g.run_sigma);
        assert!(gu.outlier_p > g.outlier_p);
    }

    #[test]
    fn deterministic_given_seed() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let p = cpu_noise(&soc, &CoreCombo::new(vec![1, 3, 0]));
        let a = p.sample_run(&mut Rng::new(3)).run_factor;
        let b = p.sample_run(&mut Rng::new(3)).run_factor;
        assert_eq!(a, b);
    }
}
