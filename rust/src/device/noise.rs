//! Measurement-noise model.
//!
//! Real measurements in the paper fluctuate with background jobs (camera,
//! sensors, networking), DVFS and inter-cluster migration; the coefficient
//! of variation grows with the number of cores used — especially small
//! ("efficiency") cores, which share the cluster with background work
//! (Fig 32, Sections 5.2/5.5.2). We model:
//!
//! - a per-run correlated log-normal factor (whole-inference slowdown),
//!   whose log-std grows with core count and small-core count;
//! - per-op i.i.d. log-normal jitter;
//! - rare heavy-tail outliers (a background job stealing the cluster).

use crate::device::{CoreCombo, Soc};
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub struct NoiseParams {
    /// Log-std of the per-run correlated factor.
    pub run_sigma: f64,
    /// Log-std of per-op jitter.
    pub op_sigma: f64,
    /// Probability that a run is an outlier.
    pub outlier_p: f64,
    /// Outlier multiplier range.
    pub outlier_lo: f64,
    pub outlier_hi: f64,
}

/// Noise parameters for a CPU scenario.
pub fn cpu_noise(soc: &Soc, combo: &CoreCombo) -> NoiseParams {
    let n = combo.total_cores();
    let small = combo.small_cores(soc);
    let hetero_extra = if combo.is_heterogeneous() { 0.008 } else { 0.0 };
    let run_sigma = soc.noise_base
        + soc.noise_per_small_core * small as f64
        + soc.noise_per_extra_core * (n - 1) as f64
        + hetero_extra;
    // Using the whole small cluster maximizes contention with background
    // jobs (the paper's worst cases: 6 small on S710, 4 small on E9820).
    let all_small = small > 0 && small == soc.clusters.iter().filter(|c| c.kind == crate::device::ClusterKind::Small).map(|c| c.count).sum::<usize>();
    let outlier_p = if all_small {
        0.035
    } else if small > 0 {
        0.02
    } else {
        0.01
    };
    NoiseParams {
        run_sigma,
        op_sigma: 0.025,
        outlier_p,
        outlier_lo: 1.4,
        outlier_hi: 3.2,
    }
}

/// Noise parameters for a GPU scenario.
pub fn gpu_noise(soc: &Soc) -> NoiseParams {
    NoiseParams {
        run_sigma: soc.gpu.run_sigma,
        op_sigma: 0.02,
        outlier_p: 0.008,
        outlier_lo: 1.3,
        outlier_hi: 2.2,
    }
}

/// Per-run sampled factors.
#[derive(Debug, Clone, Copy)]
pub struct RunNoise {
    /// Correlated multiplier applied to every op this run.
    pub run_factor: f64,
    pub op_sigma: f64,
}

impl NoiseParams {
    /// Draw this run's correlated factor (including possible outlier).
    pub fn sample_run(&self, rng: &mut Rng) -> RunNoise {
        let mut f = rng.lognormal_unit_mean(self.run_sigma);
        if rng.bool(self.outlier_p) {
            f *= rng.range_f64(self.outlier_lo, self.outlier_hi);
        }
        RunNoise { run_factor: f, op_sigma: self.op_sigma }
    }
}

impl RunNoise {
    /// Apply per-op jitter on top of the run factor.
    pub fn op_factor(&self, rng: &mut Rng) -> f64 {
        self.run_factor * rng.lognormal_unit_mean(self.op_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::soc_by_name;

    #[test]
    fn more_cores_noisier() {
        let soc = soc_by_name("Snapdragon710").unwrap();
        let one = cpu_noise(&soc, &CoreCombo::new(vec![0, 1]));
        let six = cpu_noise(&soc, &CoreCombo::new(vec![0, 6]));
        assert!(six.run_sigma > 2.0 * one.run_sigma);
        assert!(six.outlier_p > one.outlier_p);
    }

    #[test]
    fn small_cores_noisier_than_large() {
        let soc = soc_by_name("Exynos9820").unwrap();
        let large2 = cpu_noise(&soc, &CoreCombo::new(vec![2, 0, 0]));
        let small2 = cpu_noise(&soc, &CoreCombo::new(vec![0, 0, 2]));
        assert!(small2.run_sigma > large2.run_sigma);
    }

    #[test]
    fn fast_gpus_relatively_noisier() {
        // Section 5.5.2: slower GPUs show smaller relative variance.
        let mali = gpu_noise(&soc_by_name("Exynos9820").unwrap());
        let powervr = gpu_noise(&soc_by_name("HelioP35").unwrap());
        assert!(mali.run_sigma > powervr.run_sigma);
    }

    #[test]
    fn noise_is_unit_mean() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let p = cpu_noise(&soc, &CoreCombo::new(vec![1, 0, 0]));
        let mut rng = Rng::new(7);
        let n = 40_000;
        let mean: f64 =
            (0..n).map(|_| p.sample_run(&mut rng).run_factor).sum::<f64>() / n as f64;
        // Outliers push the mean slightly above 1.
        assert!((0.98..1.06).contains(&mean), "mean={mean}");
    }

    #[test]
    fn deterministic_given_seed() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let p = cpu_noise(&soc, &CoreCombo::new(vec![1, 3, 0]));
        let a = p.sample_run(&mut Rng::new(3)).run_factor;
        let b = p.sample_run(&mut Rng::new(3)).run_factor;
        assert_eq!(a, b);
    }
}
