//! The versioned device-spec schema: a SoC as *data*, not code.
//!
//! The paper's central challenge is hardware heterogeneity — predictors must
//! extend to new devices with only small amounts of profiling data (Sections
//! 1, 5.2) — so the device universe cannot be a hard-coded enum. A
//! [`SocSpec`] is the complete description of one SoC (CPU clusters with
//! frequency/throughput/bandwidth cost-model parameters, the GPU block, and
//! the studied core combinations) serialized as a small JSON document.
//! The paper's four SoCs (Table 1) are committed as spec files under
//! `device/specs/` and parsed once at startup ([`builtin_specs`]); a new
//! device is a JSON file registered via `scenario::Registry::load_spec_json`
//! (or `--device-spec` on the CLI), never a source patch.
//!
//! All numeric fields round-trip bit-exactly through `util::Json` (shortest
//! repr emit + exact parse), so scenarios and lowered plans built from a
//! re-serialized spec are bit-identical to the original — asserted by
//! `tests/device_registry.rs`.

use crate::device::{ClusterKind, CoreCluster, CoreCombo, GpuSpec, Soc};
use crate::tflite::GpuKind;
use crate::util::Json;

/// Identifies a device-spec JSON document.
pub const SPEC_FORMAT: &str = "edgelat.device_spec";
/// Schema version this build writes and reads.
pub const SPEC_VERSION: u64 = 1;

/// A complete, self-describing SoC: the simulator/cost-model parameters
/// ([`Soc`]) plus the CPU core combinations studied for it (the combos that
/// become scenarios, per Figs 2/15/23).
#[derive(Debug, Clone, PartialEq)]
pub struct SocSpec {
    pub soc: Soc,
    /// Studied core combos, `combos[i][k]` = cores from `soc.clusters[k]`.
    pub combos: Vec<Vec<usize>>,
}

/// Serialize a [`Soc`] (without combos/format envelope) — shared between
/// [`SocSpec::to_json`] and the v3 predictor-bundle descriptor, which embeds
/// the SoC so a bundle for a never-seen device loads without its spec file.
pub fn soc_to_json(soc: &Soc) -> Json {
    let clusters = soc
        .clusters
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("kind", Json::str(c.kind.name())),
                ("name", Json::str(c.name.clone())),
                ("count", Json::num(c.count as f64)),
                ("ghz", Json::Num(c.ghz)),
                ("flops_per_cycle", Json::Num(c.flops_per_cycle)),
                ("int8_speedup", Json::Num(c.int8_speedup)),
                ("stream_gbps", Json::Num(c.stream_gbps)),
            ])
        })
        .collect();
    let gpu = Json::obj(vec![
        ("kind", Json::str(soc.gpu.kind.name())),
        ("name", Json::str(soc.gpu.name.clone())),
        ("gflops", Json::Num(soc.gpu.gflops)),
        ("mem_gbps", Json::Num(soc.gpu.mem_gbps)),
        ("dispatch_us", Json::Num(soc.gpu.dispatch_us)),
        ("overhead_ms", Json::Num(soc.gpu.overhead_ms)),
        ("overhead_sigma", Json::Num(soc.gpu.overhead_sigma)),
        ("run_sigma", Json::Num(soc.gpu.run_sigma)),
    ]);
    Json::obj(vec![
        ("name", Json::str(soc.name.clone())),
        ("platform", Json::str(soc.platform.clone())),
        ("clusters", Json::Arr(clusters)),
        ("gpu", gpu),
        ("mem_gbps", Json::Num(soc.mem_gbps)),
        ("cpu_op_overhead_us", Json::Num(soc.cpu_op_overhead_us)),
        ("cpu_overhead_ms", Json::Num(soc.cpu_overhead_ms)),
        ("hetero_sync_mult", Json::Num(soc.hetero_sync_mult)),
        ("quant_ew_penalty", Json::Num(soc.quant_ew_penalty)),
        ("noise_base", Json::Num(soc.noise_base)),
        ("noise_per_small_core", Json::Num(soc.noise_per_small_core)),
        ("noise_per_extra_core", Json::Num(soc.noise_per_extra_core)),
    ])
}

/// Parse a [`Soc`] from the object emitted by [`soc_to_json`]. Structural
/// errors only; semantic validation lives in [`SocSpec::validate`].
pub fn soc_from_json(j: &Json) -> Result<Soc, String> {
    let name = j.req_str("name")?.to_string();
    let platform = j.req_str("platform")?.to_string();
    let Json::Arr(cl) = j.req("clusters")? else {
        return Err("'clusters' is not an array".into());
    };
    let mut clusters = Vec::with_capacity(cl.len());
    for (i, c) in cl.iter().enumerate() {
        let kind_name = c.req_str("kind").map_err(|e| format!("clusters[{i}]: {e}"))?;
        let kind = ClusterKind::parse(kind_name).ok_or_else(|| {
            format!("clusters[{i}]: unknown kind '{kind_name}' (large|medium|small)")
        })?;
        clusters.push(CoreCluster {
            kind,
            name: c.req_str("name").map_err(|e| format!("clusters[{i}]: {e}"))?.to_string(),
            count: c.req_usize("count").map_err(|e| format!("clusters[{i}]: {e}"))?,
            ghz: c.req_f64("ghz").map_err(|e| format!("clusters[{i}]: {e}"))?,
            flops_per_cycle: c
                .req_f64("flops_per_cycle")
                .map_err(|e| format!("clusters[{i}]: {e}"))?,
            int8_speedup: c.req_f64("int8_speedup").map_err(|e| format!("clusters[{i}]: {e}"))?,
            stream_gbps: c.req_f64("stream_gbps").map_err(|e| format!("clusters[{i}]: {e}"))?,
        });
    }
    let gj = j.req("gpu")?;
    let gpu_kind_name = gj.req_str("kind").map_err(|e| format!("gpu: {e}"))?;
    let gpu = GpuSpec {
        kind: GpuKind::parse(gpu_kind_name).ok_or_else(|| {
            format!("gpu: unknown kind '{gpu_kind_name}' (Adreno6xx|Adreno|Mali|PowerVR|AMD)")
        })?,
        name: gj.req_str("name").map_err(|e| format!("gpu: {e}"))?.to_string(),
        gflops: gj.req_f64("gflops").map_err(|e| format!("gpu: {e}"))?,
        mem_gbps: gj.req_f64("mem_gbps").map_err(|e| format!("gpu: {e}"))?,
        dispatch_us: gj.req_f64("dispatch_us").map_err(|e| format!("gpu: {e}"))?,
        overhead_ms: gj.req_f64("overhead_ms").map_err(|e| format!("gpu: {e}"))?,
        overhead_sigma: gj.req_f64("overhead_sigma").map_err(|e| format!("gpu: {e}"))?,
        run_sigma: gj.req_f64("run_sigma").map_err(|e| format!("gpu: {e}"))?,
    };
    Ok(Soc {
        name,
        platform,
        clusters,
        gpu,
        mem_gbps: j.req_f64("mem_gbps")?,
        cpu_op_overhead_us: j.req_f64("cpu_op_overhead_us")?,
        cpu_overhead_ms: j.req_f64("cpu_overhead_ms")?,
        hetero_sync_mult: j.req_f64("hetero_sync_mult")?,
        quant_ew_penalty: j.req_f64("quant_ew_penalty")?,
        noise_base: j.req_f64("noise_base")?,
        noise_per_small_core: j.req_f64("noise_per_small_core")?,
        noise_per_extra_core: j.req_f64("noise_per_extra_core")?,
    })
}

impl SocSpec {
    pub fn new(soc: Soc, combos: Vec<Vec<usize>>) -> SocSpec {
        SocSpec { soc, combos }
    }

    /// Scenarios this spec yields when registered: combos x {fp32, int8}
    /// plus the GPU.
    pub fn scenario_count(&self) -> usize {
        self.combos.len() * 2 + 1
    }

    pub fn to_json(&self) -> Json {
        let Json::Obj(mut m) = soc_to_json(&self.soc) else {
            unreachable!("soc_to_json emits an object")
        };
        m.insert("format".into(), Json::str(SPEC_FORMAT));
        m.insert("version".into(), Json::Num(SPEC_VERSION as f64));
        m.insert(
            "combos".into(),
            Json::Arr(
                self.combos
                    .iter()
                    .map(|c| Json::Arr(c.iter().map(|&n| Json::num(n as f64)).collect()))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Parse and validate a spec document.
    pub fn from_json(j: &Json) -> Result<SocSpec, String> {
        let format = j.req_str("format")?;
        if format != SPEC_FORMAT {
            return Err(format!(
                "not a device spec (format '{format}', expected '{SPEC_FORMAT}')"
            ));
        }
        let version = j.req_usize("version")? as u64;
        if version != SPEC_VERSION {
            return Err(format!(
                "unsupported device-spec version {version} (this build reads version {SPEC_VERSION})"
            ));
        }
        let soc = soc_from_json(j)?;
        let Json::Arr(cj) = j.req("combos")? else {
            return Err("'combos' is not an array".into());
        };
        let mut combos = Vec::with_capacity(cj.len());
        for (i, c) in cj.iter().enumerate() {
            combos.push(c.usize_arr().map_err(|e| format!("combos[{i}] {e}"))?);
        }
        let spec = SocSpec { soc, combos };
        spec.validate()?;
        Ok(spec)
    }

    /// Semantic validation: the SoC parameters ([`validate_soc`]), plus
    /// every combo realizable and the combo set free of duplicate scenario
    /// labels.
    pub fn validate(&self) -> Result<(), String> {
        let soc = &self.soc;
        validate_soc(soc)?;
        if self.combos.is_empty() {
            return Err(format!("soc '{}': no studied core combos", soc.name));
        }
        let mut labels = Vec::with_capacity(self.combos.len());
        for c in &self.combos {
            let combo = CoreCombo::new(c.clone());
            combo.validate(soc).map_err(|e| format!("soc '{}': combo {c:?}: {e}", soc.name))?;
            let label = combo.label(soc);
            if labels.contains(&label) {
                return Err(format!(
                    "soc '{}': combo {c:?} duplicates scenario label '{label}'",
                    soc.name
                ));
            }
            labels.push(label);
        }
        Ok(())
    }
}

/// Validate a [`Soc`]'s parameters: every field in its physical range and
/// clusters fastest-first (scenario headline/`one_large_core` assume
/// `clusters[0]` is the fastest). Shared by [`SocSpec::validate`] and the
/// v3 predictor-bundle loader, which validates the embedded device
/// descriptor the same way a spec file is validated.
pub fn validate_soc(soc: &Soc) -> Result<(), String> {
    if soc.name.is_empty() {
        return Err("soc name is empty".into());
    }
    for bad in ['/', ',', '#', '@'] {
        if soc.name.contains(bad) {
            return Err(format!(
                "soc name '{}' contains '{bad}' (reserved by scenario ids and CLI lists)",
                soc.name
            ));
        }
    }
    if soc.platform.is_empty() {
        return Err(format!("soc '{}': platform is empty", soc.name));
    }
    if soc.clusters.is_empty() {
        return Err(format!("soc '{}': no CPU clusters", soc.name));
    }
    let pos = |v: f64, what: &str| -> Result<(), String> {
        if !v.is_finite() || v <= 0.0 {
            return Err(format!(
                "soc '{}': {what} must be a positive finite number, got {v}",
                soc.name
            ));
        }
        Ok(())
    };
    let nonneg = |v: f64, what: &str| -> Result<(), String> {
        if !v.is_finite() || v < 0.0 {
            return Err(format!(
                "soc '{}': {what} must be a non-negative finite number, got {v}",
                soc.name
            ));
        }
        Ok(())
    };
    for (i, c) in soc.clusters.iter().enumerate() {
        if c.name.is_empty() {
            return Err(format!("soc '{}': clusters[{i}] name is empty", soc.name));
        }
        if c.count == 0 || c.count > 64 {
            return Err(format!(
                "soc '{}': cluster '{}' has {} cores (want 1..=64)",
                soc.name, c.name, c.count
            ));
        }
        pos(c.ghz, "cluster ghz")?;
        pos(c.flops_per_cycle, "cluster flops_per_cycle")?;
        pos(c.int8_speedup, "cluster int8_speedup")?;
        pos(c.stream_gbps, "cluster stream_gbps")?;
    }
    for w in soc.clusters.windows(2) {
        if w[0].peak_gflops() < w[1].peak_gflops() {
            return Err(format!(
                "soc '{}': clusters must be listed fastest-first ('{}' is slower than '{}')",
                soc.name, w[0].name, w[1].name
            ));
        }
    }
    if soc.gpu.name.is_empty() {
        return Err(format!("soc '{}': gpu name is empty", soc.name));
    }
    pos(soc.gpu.gflops, "gpu gflops")?;
    pos(soc.gpu.mem_gbps, "gpu mem_gbps")?;
    pos(soc.gpu.dispatch_us, "gpu dispatch_us")?;
    nonneg(soc.gpu.overhead_ms, "gpu overhead_ms")?;
    nonneg(soc.gpu.overhead_sigma, "gpu overhead_sigma")?;
    nonneg(soc.gpu.run_sigma, "gpu run_sigma")?;
    pos(soc.mem_gbps, "mem_gbps")?;
    pos(soc.cpu_op_overhead_us, "cpu_op_overhead_us")?;
    nonneg(soc.cpu_overhead_ms, "cpu_overhead_ms")?;
    if !soc.hetero_sync_mult.is_finite() || soc.hetero_sync_mult < 1.0 {
        return Err(format!(
            "soc '{}': hetero_sync_mult must be >= 1 (a penalty multiplier), got {}",
            soc.name, soc.hetero_sync_mult
        ));
    }
    if !soc.quant_ew_penalty.is_finite() || soc.quant_ew_penalty < 1.0 {
        return Err(format!(
            "soc '{}': quant_ew_penalty must be >= 1, got {}",
            soc.name, soc.quant_ew_penalty
        ));
    }
    nonneg(soc.noise_base, "noise_base")?;
    nonneg(soc.noise_per_small_core, "noise_per_small_core")?;
    nonneg(soc.noise_per_extra_core, "noise_per_extra_core")?;
    Ok(())
}

/// The four committed Table 1 specs, file name + contents (baked in via
/// `include_str!` so the binary needs no data directory).
const BUILTIN_SPEC_FILES: [(&str, &str); 4] = [
    ("snapdragon855.json", include_str!("specs/snapdragon855.json")),
    ("snapdragon710.json", include_str!("specs/snapdragon710.json")),
    ("exynos9820.json", include_str!("specs/exynos9820.json")),
    ("helio_p35.json", include_str!("specs/helio_p35.json")),
];

/// The paper's four SoCs, parsed and validated once from the committed spec
/// files. Panics only on a corrupted build (the specs ship inside the
/// binary and are covered by tests).
pub fn builtin_specs() -> &'static [SocSpec] {
    static SPECS: std::sync::OnceLock<Vec<SocSpec>> = std::sync::OnceLock::new();
    SPECS.get_or_init(|| {
        BUILTIN_SPEC_FILES
            .iter()
            .map(|(file, text)| {
                let j = Json::parse(text)
                    .unwrap_or_else(|e| panic!("builtin device spec {file}: {e}"));
                SocSpec::from_json(&j)
                    .unwrap_or_else(|e| panic!("builtin device spec {file}: {e}"))
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_parse_and_validate() {
        let specs = builtin_specs();
        assert_eq!(specs.len(), 4);
        let names: Vec<&str> = specs.iter().map(|s| s.soc.name.as_str()).collect();
        assert_eq!(
            names,
            ["Snapdragon855", "Snapdragon710", "Exynos9820", "HelioP35"]
        );
        // 34 CPU combos x 2 reps + 4 GPUs = 72 scenarios (Section 4.3).
        let total: usize = specs.iter().map(|s| s.scenario_count()).sum();
        assert_eq!(total, 72);
    }

    #[test]
    fn spec_json_roundtrip_is_exact() {
        for spec in builtin_specs() {
            let text = spec.to_json().to_string();
            let back = SocSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            // PartialEq over every f64 — bit-exact via the emitter/parser.
            assert_eq!(&back, spec, "{}", spec.soc.name);
        }
    }

    #[test]
    fn invalid_specs_are_rejected_with_reasons() {
        let base = builtin_specs()[0].clone();

        let mut slash = base.clone();
        slash.soc.name = "My/Soc".into();
        assert!(slash.validate().unwrap_err().contains("reserved"));

        let mut dup = base.clone();
        let first = dup.combos[0].clone();
        dup.combos.push(first);
        assert!(dup.validate().unwrap_err().contains("duplicates"));

        let mut empty = base.clone();
        empty.combos.clear();
        assert!(empty.validate().unwrap_err().contains("combos"));

        let mut overdrawn = base.clone();
        overdrawn.combos.push(vec![9, 0, 0]);
        assert!(overdrawn.validate().is_err());

        let mut slow_first = base.clone();
        slow_first.clusters_reverse();
        assert!(slow_first.validate().unwrap_err().contains("fastest-first"));

        let mut bad_ghz = base.clone();
        bad_ghz.soc.clusters[0].ghz = -1.0;
        assert!(bad_ghz.validate().unwrap_err().contains("ghz"));

        let mut bad_sync = base;
        bad_sync.soc.hetero_sync_mult = 0.5;
        assert!(bad_sync.validate().unwrap_err().contains("hetero_sync_mult"));
    }

    impl SocSpec {
        /// Test helper: reverse cluster order (and combo arity with it).
        fn clusters_reverse(&mut self) {
            self.soc.clusters.reverse();
            for c in &mut self.combos {
                c.reverse();
            }
        }
    }

    #[test]
    fn from_json_rejects_wrong_envelope() {
        let err = SocSpec::from_json(&Json::parse("{}").unwrap()).unwrap_err();
        assert!(err.contains("format"), "{err}");
        let j = Json::obj(vec![("format", Json::str("something.else"))]);
        assert!(SocSpec::from_json(&j).unwrap_err().contains("not a device spec"));
        let mut v9 = builtin_specs()[0].to_json();
        if let Json::Obj(m) = &mut v9 {
            m.insert("version".into(), Json::Num(9.0));
        }
        assert!(SocSpec::from_json(&v9).unwrap_err().contains("version 9"));
        let mut bad_gpu = builtin_specs()[0].to_json();
        if let Json::Obj(m) = &mut bad_gpu {
            let Some(Json::Obj(g)) = m.get_mut("gpu") else { panic!() };
            g.insert("kind".into(), Json::str("Voodoo3"));
        }
        assert!(SocSpec::from_json(&bad_gpu).unwrap_err().contains("Voodoo3"));
    }
}
