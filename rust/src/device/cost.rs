//! Noise-free cost models for CPU operations and GPU kernels.
//!
//! Roofline-style: latency = max(compute time, memory time) + fixed
//! overhead, with empirically-shaped efficiency factors (narrow channels
//! and small kernels run below peak, depthwise convolutions are memory
//! bound, Ruy splits work equally across threads so heterogeneous combos
//! straggle on the slowest core — Insight 1).

use crate::device::{CoreCombo, DataRep, Soc};
use crate::graph::{Graph, Node, Op, OpType, Shape};
use crate::tflite::{FusedKernel, KernelImpl};
use crate::workload::{self, WorkloadSpec};

/// Fraction of peak a convolution achieves as a function of its narrowest
/// channel dimension: Ruy/GEMM kernels need wide panels to fill NEON lanes.
/// The curve is mild (≈1.9x between 8 and 64 channels) — real Ruy/OpenCL
/// GEMMs stay closer to linear-in-FLOPs than a naive occupancy model, which
/// is what lets the paper's *linear* Lasso stay in the ~10% MAPE band.
fn chan_eff(c: usize) -> f64 {
    ((c as f64 / 64.0).powf(0.22)).clamp(0.35, 1.0)
}

/// CPU variant: Ruy's cache-blocked GEMM keeps narrow-panel efficiency much
/// flatter than a GPU's occupancy curve; the *memory* term (streamed at the
/// low effective per-core bandwidth) is what slows narrow architectures
/// down. The CPU cost is additive — compute + memory + dispatch — which is
/// near-linear in the Table 3 features; that additivity is what keeps the
/// paper's *linear* Lasso predictor in its ~10% end-to-end band on CPUs,
/// while trees exploit the residual curvature.
fn cpu_chan_eff(c: usize) -> f64 {
    ((c as f64 / 64.0).powf(0.35)).clamp(0.30, 1.0)
}

/// Kernel-size efficiency: 1x1 convs are pure GEMM but memory-heavier;
/// larger kernels amortize loads.
fn kernel_eff(k: usize) -> f64 {
    match k {
        1 => 0.78,
        3 => 1.0,
        5 => 0.95,
        _ => 0.90,
    }
}

/// Multithreading efficiency loss per extra thread (work-queue overhead),
/// yielding the sublinear homogeneous scaling of Fig 3.
fn par_eff(threads: usize) -> f64 {
    1.0 / (1.0 + 0.07 * (threads as f64 - 1.0))
}

/// Bytes moved by an op on the CPU (activations at `rep` precision,
/// weights at `rep` precision).
fn cpu_bytes(node: &Node, ins: &[Shape], outs: &[Shape], rep: DataRep) -> f64 {
    let act = rep.bytes();
    let i: f64 = ins.iter().map(|s| s.numel() as f64).sum::<f64>() * act;
    let o: f64 = outs.iter().map(|s| s.numel() as f64).sum::<f64>() * act;
    let p = node.op.param_count(ins, outs) as f64 * act;
    match node.op {
        // Convs re-read input patches; the factor is folded into efficiency,
        // traffic is in + out + weights.
        Op::Conv2D { .. } | Op::DepthwiseConv2D { .. } | Op::FullyConnected { .. } => i + o + p,
        // Concat/split are pure copies: read + write.
        Op::Concat | Op::Split { .. } => i + o,
        Op::Pad { .. } => o,
        Op::Softmax => 3.0 * i,
        Op::Reshape => 0.0, // view
        // Standalone activations mostly run on cache-resident data right
        // after their producer (TFLite fuses them into the conv kernels).
        Op::Activation { .. } => 0.25 * (i + o),
        _ => i + o,
    }
}

/// Compute-efficiency factor for an op on a CPU core.
fn cpu_eff(node: &Node, ins: &[Shape], outs: &[Shape]) -> f64 {
    match &node.op {
        Op::Conv2D { kh, groups, out_c, .. } => {
            let in_g = ins[0].c / groups;
            let out_g = out_c / groups;
            0.78 * cpu_chan_eff(in_g.min(out_g)) * kernel_eff(*kh)
        }
        Op::DepthwiseConv2D { .. } => 0.30 * ((outs[0].c as f64 / 128.0).powf(0.1)).clamp(0.8, 1.0),
        Op::FullyConnected { .. } => 0.40,
        Op::Pooling { .. } => 0.12,
        Op::Mean => 0.10,
        Op::ElementWise { .. } | Op::Activation { .. } => 0.12,
        Op::Softmax => 0.08,
        _ => 0.10,
    }
}

/// Quantized-compute speedup class of an op (Insight 2): matmul-family ops
/// gain the cluster's dot-product speedup; element-wise/pad *lose* from
/// rescaling; the rest gain modestly.
enum QuantClass {
    Matmul,
    Penalized,
    Modest,
    Copy,
}

fn quant_class(op: &Op) -> QuantClass {
    match op {
        Op::Conv2D { .. } | Op::DepthwiseConv2D { .. } | Op::FullyConnected { .. } => {
            QuantClass::Matmul
        }
        Op::ElementWise { .. } | Op::Pad { .. } => QuantClass::Penalized,
        Op::Concat | Op::Split { .. } | Op::Reshape => QuantClass::Copy,
        _ => QuantClass::Modest,
    }
}

/// Noise-free latency (ms) of one op on the CPU under a core combo.
///
/// `serial_cluster` is the cluster index executing non-parallelizable ops
/// this run (TFLite schedules them on an arbitrary core of the affinity
/// set — Section 5.2 notes this complicates heterogeneous prediction).
pub fn cpu_op_ms(
    soc: &Soc,
    g: &Graph,
    node: &Node,
    combo: &CoreCombo,
    rep: DataRep,
    serial_cluster: usize,
) -> f64 {
    // Multiplying the phases by exactly 1.0 is an IEEE no-op, so the
    // isolated path stays bit-identical to the pre-workload model.
    cpu_op_ms_scaled(soc, g, node, combo, rep, serial_cluster, 1.0, 1.0)
}

/// [`cpu_op_ms`] under an optional workload: whole-batch latency, with the
/// workload's contention multipliers on the variable compute/memory phases
/// scaled by the batch-amortization factor, while the per-op fixed
/// overhead is paid once per batch. `None` is bit-identical to
/// [`cpu_op_ms`].
pub fn cpu_op_ms_under(
    soc: &Soc,
    g: &Graph,
    node: &Node,
    combo: &CoreCombo,
    rep: DataRep,
    serial_cluster: usize,
    wl: Option<&WorkloadSpec>,
) -> f64 {
    match wl {
        None => cpu_op_ms(soc, g, node, combo, rep, serial_cluster),
        Some(wl) => {
            let load = wl.combo_load(combo);
            let bm = wl.batch_work_mult();
            cpu_op_ms_scaled(
                soc,
                g,
                node,
                combo,
                rep,
                serial_cluster,
                workload::cpu_compute_mult(load) * bm,
                workload::cpu_mem_mult(load) * bm,
            )
        }
    }
}

/// The shared CPU roofline with explicit multipliers on the variable
/// phases — `(1.0, 1.0)` reproduces the isolated model bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn cpu_op_ms_scaled(
    soc: &Soc,
    g: &Graph,
    node: &Node,
    combo: &CoreCombo,
    rep: DataRep,
    serial_cluster: usize,
    compute_mult: f64,
    mem_mult: f64,
) -> f64 {
    let ins = g.input_shapes(node);
    let outs = g.output_shapes(node);
    let flops = node.op.flops(&ins, &outs) as f64;
    let eff = cpu_eff(node, &ins, &outs);
    let overhead_ms = soc.cpu_op_overhead_us / 1e3;

    let quant = matches!(rep, DataRep::Int8);
    let class = quant_class(&node.op);
    // Element-wise/pad ops under int8 pay the rescale penalty on their full
    // fp32-equivalent cost (Insight 2): they move int8 data but re-quantize
    // every element, ending up *slower* than fp32.
    let penalized = quant && matches!(class, QuantClass::Penalized);
    let bytes_rep = if penalized { DataRep::Fp32 } else { rep };
    let bytes = cpu_bytes(node, &ins, &outs, bytes_rep);

    let core_gflops = |cluster: usize| -> f64 {
        let cl = &soc.clusters[cluster];
        let mut peak = cl.peak_gflops();
        if quant {
            peak *= match class {
                QuantClass::Matmul => cl.int8_speedup,
                QuantClass::Modest => 1.3,
                _ => 1.0,
            };
        }
        peak
    };

    // Compute and memory phases. Cost is ADDITIVE (compute + stream), which
    // is what Ruy's pack->multiply pipeline approximates and what makes the
    // per-op latency near-linear in the Table 3 features.
    let (compute_ms, mem_ms) = if node.op.cpu_parallel() && combo.total_cores() > 1 {
        // Ruy splits the work *equally* across threads; the slowest core
        // becomes the straggler (Insight 1).
        let cores = combo.cores();
        let t = cores.len();
        let fshare = flops / t as f64;
        let bshare = bytes / t as f64;
        let slowest_c = cores
            .iter()
            .map(|&cl| fshare / (eff * par_eff(t) * core_gflops(cl) * 1e6))
            .fold(0.0f64, f64::max);
        let slowest_m = cores
            .iter()
            .map(|&cl| bshare / (soc.clusters[cl].stream_gbps * par_eff(t) * 1e6))
            .fold(0.0f64, f64::max);
        let hetero = combo.is_heterogeneous();
        let sync_us =
            8.0 * ((t - 1) as f64).sqrt() * if hetero { soc.hetero_sync_mult } else { 1.0 };
        (slowest_c + sync_us / 1e3, slowest_m)
    } else {
        let cl = if node.op.cpu_parallel() { combo.cores()[0] } else { serial_cluster };
        (
            flops / (eff * core_gflops(cl) * 1e6),
            bytes / (soc.clusters[cl].stream_gbps * 1e6),
        )
    };

    let mut ms = compute_ms * compute_mult + mem_ms * mem_mult + overhead_ms;
    if penalized {
        // Rescaling all inputs to a common quantization scale costs more
        // than the int8 arithmetic saves (Insight 2; ~2.5x on S855/E9820).
        ms *= soc.quant_ew_penalty;
    }
    ms
}

/// GPU activation/weight byte width (the TFLite GPU delegate computes in
/// fp16 on all four devices).
const GPU_ACT_BYTES: f64 = 2.0;

fn gpu_eff(impl_: KernelImpl, root: &Node, ins: &[Shape]) -> f64 {
    match impl_ {
        KernelImpl::Conv2D => {
            if let Op::Conv2D { kh, out_c, .. } = root.op {
                0.50 * chan_eff(ins[0].c.min(out_c)) * kernel_eff(kh)
            } else {
                0.40
            }
        }
        KernelImpl::Winograd => {
            if let Op::Conv2D { out_c, .. } = root.op {
                0.48 * chan_eff(ins[0].c.min(out_c))
            } else {
                0.48
            }
        }
        KernelImpl::GroupedConv2D => {
            if let Op::Conv2D { groups, out_c, .. } = root.op {
                0.42 * chan_eff((ins[0].c / groups).min(out_c / groups))
            } else {
                0.42
            }
        }
        KernelImpl::NaiveGroupedConv2D { .. } => 0.42, // handled per group below
        KernelImpl::DepthwiseConv2D => 0.13,
        KernelImpl::FullyConnected => 0.25,
        KernelImpl::Generic => 0.08,
    }
}

/// Noise-free latency (ms) of one compiled GPU kernel.
pub fn gpu_kernel_ms(soc: &Soc, g: &Graph, k: &FusedKernel) -> f64 {
    // busy_mult == 1.0 is an IEEE no-op: bit-identical isolated path.
    gpu_kernel_ms_scaled(soc, g, k, 1.0)
}

/// [`gpu_kernel_ms`] under an optional workload: busy time (the roofline
/// max of compute and memory, and the split/concat copies of the naive
/// grouped path) stretches by the quota multiplier and the whole-batch
/// work factor; per-dispatch overhead is paid once per batch regardless of
/// who holds the GPU. `None` is bit-identical to [`gpu_kernel_ms`].
pub fn gpu_kernel_ms_under(soc: &Soc, g: &Graph, k: &FusedKernel, wl: Option<&WorkloadSpec>) -> f64 {
    match wl {
        None => gpu_kernel_ms(soc, g, k),
        Some(wl) => {
            let busy = workload::gpu_quota_mult(wl.gpu_share) * wl.batch_work_mult();
            gpu_kernel_ms_scaled(soc, g, k, busy)
        }
    }
}

/// The shared GPU roofline with an explicit multiplier on every busy-time
/// term — `1.0` reproduces the isolated model bit-for-bit.
fn gpu_kernel_ms_scaled(soc: &Soc, g: &Graph, k: &FusedKernel, busy_mult: f64) -> f64 {
    let gpu = &soc.gpu;
    let root = &g.nodes[k.root()];
    let ins = g.input_shapes(root);
    let outs = g.output_shapes(root);
    let dispatch_ms = gpu.dispatch_us / 1e3;

    if let KernelImpl::NaiveGroupedConv2D { groups } = k.impl_ {
        // split + per-group Conv2D kernels + concat, each dispatched. Each
        // per-group convolution runs at the (low) occupancy of its narrow
        // channel slice — the source of the paper's up-to-3x gap (Fig 9).
        let flops = root.op.flops(&ins, &outs) as f64;
        let params = root.op.param_count(&ins, &outs) as f64;
        let in_b = ins[0].numel() as f64 * GPU_ACT_BYTES;
        let out_b = outs[0].numel() as f64 * GPU_ACT_BYTES;
        let (kh, per_group_c) = match root.op {
            crate::graph::Op::Conv2D { kh, out_c, .. } => {
                (kh, (ins[0].c / groups).min(out_c / groups))
            }
            _ => (3, 8),
        };
        let naive_eff = 0.50 * chan_eff(per_group_c) * kernel_eff(kh);
        let per_group_compute = (flops / groups as f64) / (naive_eff * gpu.gflops * 1e6);
        let per_group_mem =
            ((in_b + out_b) / groups as f64 + params * GPU_ACT_BYTES / groups as f64)
                / (gpu.mem_gbps * 1e9)
                * 1e3;
        let group_ms: f64 = (0..groups)
            .map(|_| per_group_compute.max(per_group_mem) * busy_mult + dispatch_ms)
            .sum();
        // split: read+write input; concat: read+write output.
        let split_ms = 2.0 * in_b / (gpu.mem_gbps * 1e9) * 1e3 * busy_mult + dispatch_ms;
        let concat_ms = 2.0 * out_b / (gpu.mem_gbps * 1e9) * 1e3 * busy_mult + dispatch_ms;
        return split_ms + group_ms + concat_ms;
    }

    let mut flops = root.op.flops(&ins, &outs) as f64;
    let eff = gpu_eff(k.impl_, root, &ins);
    let mut mem_mult = 1.0;
    if k.impl_ == KernelImpl::Winograd {
        // F(4x4, 3x3): 36/16 = 2.25x arithmetic reduction; tile transforms
        // add memory traffic.
        flops /= 2.3;
        mem_mult = 1.25;
    }

    // Fused linkable ops execute in-register: their FLOPs ride along at low
    // cost and their intermediate tensors never touch memory. Extra inputs
    // (e.g. residual shortcuts) are read once.
    let mut fused_flops = 0.0;
    for &op in k.fused_ops() {
        let n = &g.nodes[op];
        fused_flops += n.op.flops(&g.input_shapes(n), &g.output_shapes(n)) as f64;
    }

    let src_b: f64 = k.src.iter().map(|&t| g.shape(t).numel() as f64).sum::<f64>() * GPU_ACT_BYTES;
    let dst_b: f64 = k.dst.iter().map(|&t| g.shape(t).numel() as f64).sum::<f64>() * GPU_ACT_BYTES;
    let param_b = root.op.param_count(&ins, &outs) as f64 * GPU_ACT_BYTES;

    let compute_ms = (flops / eff + fused_flops / 0.30) / (gpu.gflops * 1e6);
    let mem_ms = (src_b * mem_mult + dst_b + param_b) / (gpu.mem_gbps * 1e9) * 1e3;
    compute_ms.max(mem_ms) * busy_mult + dispatch_ms
}

/// Coarse op-type of a fused kernel for breakdown figures (root op's type).
pub fn kernel_op_type(g: &Graph, k: &FusedKernel) -> OpType {
    g.nodes[k.root()].op.op_type()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{soc_by_name, CoreCombo};
    use crate::graph::{ActKind, GraphBuilder, Padding};
    use crate::tflite::{compile, CompileOptions, GpuKind};

    fn conv_graph(c_in: usize, c_out: usize, hw: usize, k: usize) -> Graph {
        let mut b = GraphBuilder::new("t", hw, hw, c_in);
        let x = b.input_tensor();
        let t = b.conv(x, c_out, k, 1, Padding::Same);
        b.finish(vec![t])
    }

    #[test]
    fn larger_convs_cost_more() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 0, 0]);
        let small = conv_graph(32, 32, 28, 3);
        let big = conv_graph(64, 64, 56, 3);
        let a = cpu_op_ms(&soc, &small, &small.nodes[0], &combo, DataRep::Fp32, 0);
        let b = cpu_op_ms(&soc, &big, &big.nodes[0], &combo, DataRep::Fp32, 0);
        assert!(b > 4.0 * a, "a={a} b={b}");
    }

    #[test]
    fn homogeneous_multicore_speedup_is_sublinear() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let g = conv_graph(64, 128, 56, 3);
        let one = cpu_op_ms(&soc, &g, &g.nodes[0], &CoreCombo::new(vec![0, 1, 0]), DataRep::Fp32, 1);
        let three =
            cpu_op_ms(&soc, &g, &g.nodes[0], &CoreCombo::new(vec![0, 3, 0]), DataRep::Fp32, 1);
        let speedup = one / three;
        assert!(speedup > 1.6 && speedup < 3.0, "speedup={speedup}");
    }

    #[test]
    fn hetero_combo_straggles_below_fast_core_alone() {
        // Insight 1: medium + small can be slower than medium alone.
        let soc = soc_by_name("Snapdragon855").unwrap();
        let g = conv_graph(64, 128, 56, 3);
        let medium =
            cpu_op_ms(&soc, &g, &g.nodes[0], &CoreCombo::new(vec![0, 1, 0]), DataRep::Fp32, 1);
        let med_small =
            cpu_op_ms(&soc, &g, &g.nodes[0], &CoreCombo::new(vec![0, 1, 1]), DataRep::Fp32, 1);
        assert!(
            med_small > medium * 0.95,
            "medium={medium} med+small={med_small}: small core should straggle"
        );
    }

    #[test]
    fn int8_speeds_up_convs() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 0, 0]);
        let g = conv_graph(64, 128, 56, 3);
        let f = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Fp32, 0);
        let q = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Int8, 0);
        assert!(f / q > 1.8, "fp32={f} int8={q}");
    }

    #[test]
    fn int8_degrades_elementwise() {
        // Insight 2: element-wise ops slow down ~2.5x after quantization.
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 0, 0]);
        let mut b = GraphBuilder::new("t", 56, 56, 64);
        let x = b.input_tensor();
        let t = b.ew_const(crate::graph::EwKind::Abs, x);
        let g = b.finish(vec![t]);
        let f = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Fp32, 0);
        let q = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Int8, 0);
        assert!(q / f > 1.5, "fp32={f} int8={q}");
    }

    #[test]
    fn serial_ops_use_serial_cluster() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 0, 1]);
        let mut b = GraphBuilder::new("t", 56, 56, 64);
        let x = b.input_tensor();
        let t = b.softmax(x);
        let g = b.finish(vec![t]);
        let on_large = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Fp32, 0);
        let on_small = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Fp32, 2);
        assert!(on_small > on_large, "large={on_large} small={on_small}");
    }

    #[test]
    fn winograd_kernel_faster_than_conv2d() {
        let soc = soc_by_name("HelioP35").unwrap();
        let g = conv_graph(128, 128, 28, 3);
        let full = compile(&g, GpuKind::PowerVR, CompileOptions::default());
        assert_eq!(full.kernels[0].impl_, KernelImpl::Winograd);
        let plain = compile(
            &g,
            GpuKind::PowerVR,
            CompileOptions { winograd: false, ..Default::default() },
        );
        let w = gpu_kernel_ms(&soc, &g, &full.kernels[0]);
        let c = gpu_kernel_ms(&soc, &g, &plain.kernels[0]);
        assert!(c / w > 1.4, "conv={c} winograd={w}");
    }

    #[test]
    fn optimized_grouped_beats_naive() {
        let soc = soc_by_name("HelioP35").unwrap();
        let mut b = GraphBuilder::new("t", 28, 28, 64);
        let x = b.input_tensor();
        let t = b.grouped_conv(x, 64, 3, 1, 8);
        let g = b.finish(vec![t]);
        let opt = compile(&g, GpuKind::PowerVR, CompileOptions::default());
        assert_eq!(opt.kernels[0].impl_, KernelImpl::GroupedConv2D);
        let naive = compile(
            &g,
            GpuKind::PowerVR,
            CompileOptions { grouped: false, ..Default::default() },
        );
        let o = gpu_kernel_ms(&soc, &g, &opt.kernels[0]);
        let n = gpu_kernel_ms(&soc, &g, &naive.kernels[0]);
        assert!(n / o > 1.5, "naive={n} optimized={o}");
    }

    #[test]
    fn isolated_valued_workload_is_bit_identical_to_none() {
        // A workload whose axes sit at the isolated point (load 0, batch 1,
        // full quota) multiplies by exactly 1.0 — not merely close.
        let iso =
            WorkloadSpec { name: "iso".into(), batch: 1, cpu_load: vec![0.0], gpu_share: 1.0 };
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 2, 1]);
        let g = conv_graph(64, 128, 56, 3);
        let a = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Int8, 0);
        let b = cpu_op_ms_under(&soc, &g, &g.nodes[0], &combo, DataRep::Int8, 0, Some(&iso));
        assert_eq!(a.to_bits(), b.to_bits());
        let compiled = compile(&g, GpuKind::Adreno, CompileOptions::default());
        let x = gpu_kernel_ms(&soc, &g, &compiled.kernels[0]);
        let y = gpu_kernel_ms_under(&soc, &g, &compiled.kernels[0], Some(&iso));
        assert_eq!(x.to_bits(), y.to_bits());
    }

    #[test]
    fn contention_and_batch_inflate_whole_batch_latency() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let combo = CoreCombo::new(vec![1, 0, 0]);
        let g = conv_graph(64, 128, 56, 3);
        let iso = cpu_op_ms(&soc, &g, &g.nodes[0], &combo, DataRep::Fp32, 0);
        let loaded =
            WorkloadSpec { name: "l".into(), batch: 1, cpu_load: vec![0.8], gpu_share: 1.0 };
        let contended =
            cpu_op_ms_under(&soc, &g, &g.nodes[0], &combo, DataRep::Fp32, 0, Some(&loaded));
        assert!(contended > iso, "iso={iso} contended={contended}");
        // Batch b: whole-batch latency within [1x, b x] the single-item cost.
        let b8 = WorkloadSpec { name: "b8".into(), batch: 8, cpu_load: vec![0.0], gpu_share: 1.0 };
        let batched = cpu_op_ms_under(&soc, &g, &g.nodes[0], &combo, DataRep::Fp32, 0, Some(&b8));
        assert!(batched > iso && batched < 8.0 * iso, "iso={iso} batched={batched}");
        // GPU: a halved quota share at least doubles busy-dominated kernels'
        // busy time (dispatch is unscaled, so the total is below 2x + eps).
        let half =
            WorkloadSpec { name: "h".into(), batch: 1, cpu_load: vec![0.0], gpu_share: 0.5 };
        let compiled = compile(&g, GpuKind::Adreno, CompileOptions::default());
        let x = gpu_kernel_ms(&soc, &g, &compiled.kernels[0]);
        let y = gpu_kernel_ms_under(&soc, &g, &compiled.kernels[0], Some(&half));
        assert!(y > x && y <= 2.0 * x, "iso={x} half-quota={y}");
    }

    #[test]
    fn naive_grouped_path_scales_under_workload_too() {
        let soc = soc_by_name("HelioP35").unwrap();
        let mut b = GraphBuilder::new("t", 28, 28, 64);
        let x = b.input_tensor();
        let t = b.grouped_conv(x, 64, 3, 1, 8);
        let g = b.finish(vec![t]);
        let naive = compile(
            &g,
            GpuKind::PowerVR,
            CompileOptions { grouped: false, ..Default::default() },
        );
        assert!(matches!(naive.kernels[0].impl_, KernelImpl::NaiveGroupedConv2D { .. }));
        let wl = WorkloadSpec { name: "w".into(), batch: 4, cpu_load: vec![0.5], gpu_share: 0.5 };
        let iso = gpu_kernel_ms(&soc, &g, &naive.kernels[0]);
        let under = gpu_kernel_ms_under(&soc, &g, &naive.kernels[0], Some(&wl));
        assert!(under > iso, "iso={iso} under={under}");
    }

    #[test]
    fn fusion_reduces_kernel_total() {
        let soc = soc_by_name("Exynos9820").unwrap();
        let g = crate::zoo::mobilenets::mobilenet_v2(1.0);
        let fused = compile(&g, GpuKind::Mali, CompileOptions::default());
        let plain = compile(&g, GpuKind::Mali, CompileOptions { fusion: false, ..Default::default() });
        let t_f: f64 = fused.kernels.iter().map(|k| gpu_kernel_ms(&soc, &g, k)).sum();
        let t_p: f64 = plain.kernels.iter().map(|k| gpu_kernel_ms(&soc, &g, k)).sum();
        let speedup = t_p / t_f;
        assert!(speedup > 1.05 && speedup < 1.8, "fusion speedup {speedup}");
    }
}
