//! `edgelat workload eval` — the accuracy artifact for the contended
//! scenario universe.
//!
//! The paper's evaluation (Section 5) holds workload fixed at
//! isolated/batch-1; this sweep re-runs the train→predict loop across the
//! workload cross-product (every builtin preset plus the isolated
//! baseline on a slice of the builtin SoCs) and emits a versioned JSON
//! artifact of per-scenario end-to-end RMSPE/MAPE. The point is a
//! regression tripwire: the contention/batch multipliers are deterministic
//! cost-model inputs, so a per-op predictor trained *under* a workload
//! must stay as accurate as the isolated one — a blow-up here means the
//! feature columns and the cost model disagree. The CLI (and the CI bench
//! gate, through `derived.workload.max_rmspe`) fails when any scenario's
//! RMSPE exceeds [`RMSPE_BOUND`] or goes non-finite.

use crate::framework::{evaluate, DeductionMode, ScenarioPredictor};
use crate::predict::Method;
use crate::profiler::profile_set;
use crate::scenario::{Registry, Scenario};
use crate::util::stats::{mape_guarded, rmspe_guarded};
use crate::util::Json;
use std::sync::Arc;

/// Format tag of the workload-eval artifact.
pub const EVAL_FORMAT: &str = "edgelat.workload_eval";
/// Current artifact schema version.
pub const EVAL_VERSION: u64 = 1;
/// Per-scenario end-to-end RMSPE ceiling. Generous on purpose: typical
/// GBDT runs land under 0.1, so a breach signals a cost-model/feature
/// mismatch, not measurement jitter.
pub const RMSPE_BOUND: f64 = 1.0;

/// Sweep sizes for one eval run.
#[derive(Debug, Clone)]
pub struct EvalConfig {
    /// Profiling/training seed (the sweep is deterministic given it).
    pub seed: u64,
    /// Training NAs profiled per scenario.
    pub n_train: usize,
    /// Held-out NAs evaluated per scenario.
    pub n_test: usize,
    /// Profiling repetitions per (model, scenario).
    pub runs: usize,
    /// Builtin SoCs covered (first N in registry order; each contributes
    /// one large-core CPU scenario and its GPU).
    pub socs: usize,
}

impl EvalConfig {
    /// CI smoke scale: one SoC, every workload regime.
    pub fn quick(seed: u64) -> EvalConfig {
        EvalConfig { seed, n_train: 8, n_test: 4, runs: 2, socs: 1 }
    }

    /// Default scale for local runs: two SoCs, larger splits.
    pub fn full(seed: u64) -> EvalConfig {
        EvalConfig { seed, n_train: 24, n_test: 10, runs: 3, socs: 2 }
    }
}

/// One evaluated (scenario × workload regime) cell.
#[derive(Debug, Clone)]
pub struct ScenarioRow {
    /// Full scenario id (`BASE` or `BASE@WORKLOAD`).
    pub scenario: String,
    /// Workload name, `"-"` for the isolated baseline.
    pub workload: String,
    pub batch: usize,
    /// Max co-runner load the scenario's target experiences.
    pub load: f64,
    pub gpu_share: f64,
    /// End-to-end RMSPE over the held-out split.
    pub rmspe: f64,
    /// End-to-end MAPE over the held-out split.
    pub mape: f64,
    /// Held-out architectures evaluated.
    pub n_test: usize,
}

/// The full sweep result.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub rows: Vec<ScenarioRow>,
    pub bound: f64,
}

impl EvalReport {
    /// Worst per-scenario RMSPE; NaN-poisoning (any non-finite row makes
    /// the max non-finite, so `ok()` still fails).
    pub fn max_rmspe(&self) -> f64 {
        self.rows.iter().map(|r| r.rmspe).fold(0.0, |a, b| if b.is_nan() { b } else { a.max(b) })
    }

    /// Rows with a real workload attached (not the isolated baseline).
    pub fn contended_rows(&self) -> usize {
        self.rows.iter().filter(|r| r.workload != "-").count()
    }

    /// Every scenario finite and within the bound.
    pub fn ok(&self) -> bool {
        !self.rows.is_empty()
            && self.rows.iter().all(|r| r.rmspe.is_finite() && r.rmspe <= self.bound)
    }

    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("scenario", Json::str(r.scenario.clone())),
                    ("workload", Json::str(r.workload.clone())),
                    ("batch", Json::num(r.batch as f64)),
                    ("load", Json::num(r.load)),
                    ("gpu_share", Json::num(r.gpu_share)),
                    ("rmspe", Json::num(fin(r.rmspe))),
                    ("mape", Json::num(fin(r.mape))),
                    ("n_test", Json::num(r.n_test as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::str(EVAL_FORMAT)),
            ("version", Json::num(EVAL_VERSION as f64)),
            ("bound", Json::num(self.bound)),
            ("max_rmspe", Json::num(fin(self.max_rmspe()))),
            ("scenarios", Json::num(self.rows.len() as f64)),
            ("contended", Json::num(self.contended_rows() as f64)),
            ("ok", Json::Bool(self.ok())),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Non-finite values would emit invalid JSON; -1.0 is visibly out of range
/// for every emitted quantity (the gate checks finiteness downstream).
fn fin(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        -1.0
    }
}

/// The (scenario × regime) cells the sweep covers: for each of the first
/// `cfg.socs` builtin SoCs, one large CPU core and the GPU, each under the
/// isolated baseline plus every builtin workload preset.
fn sweep_scenarios(cfg: &EvalConfig) -> Vec<Scenario> {
    let reg = Registry::builtin();
    let presets = crate::workload::builtin_presets();
    let mut out = Vec::new();
    for soc in reg.socs().iter().take(cfg.socs.max(1)) {
        let cpu = reg.one_large_core(&soc.name).expect("builtin SoC has a large core");
        let gpu = Scenario::gpu(soc);
        for base in [cpu, gpu] {
            out.push(base.clone());
            for wl in presets {
                out.push(base.with_workload(Arc::new(wl.clone())));
            }
        }
    }
    out
}

/// Run the sweep: train a GBDT per scenario on profiled synthetic NAs and
/// score the held-out split end-to-end. Deterministic given `cfg.seed`.
pub fn run(cfg: &EvalConfig) -> EvalReport {
    let train_g: Vec<crate::graph::Graph> =
        crate::nas::sample_dataset(cfg.seed, cfg.n_train).into_iter().map(|a| a.graph).collect();
    let test_g: Vec<crate::graph::Graph> = crate::nas::sample_dataset(cfg.seed ^ 0x3a7e, cfg.n_test)
        .into_iter()
        .map(|a| a.graph)
        .collect();
    let mut rows = Vec::new();
    for sc in sweep_scenarios(cfg) {
        let train_p = profile_set(&sc, &train_g, cfg.seed, cfg.runs);
        let test_p = profile_set(&sc, &test_g, cfg.seed ^ 0x7e57, cfg.runs);
        let pred = ScenarioPredictor::train_from(
            &sc,
            &train_p,
            Method::Gbdt,
            DeductionMode::Full,
            cfg.seed,
            None,
        );
        let ev = evaluate(&pred, &test_g, &test_p);
        let (pred_e2e, meas_e2e): (Vec<f64>, Vec<f64>) =
            ev.predictions.iter().map(|(_, p, m)| (*p, *m)).unzip();
        let (rmspe, _) = rmspe_guarded(&pred_e2e, &meas_e2e);
        let (mape, _) = mape_guarded(&pred_e2e, &meas_e2e);
        let (workload, batch, load, gpu_share) = match &sc.workload {
            Some(wl) => {
                let load = match &sc.target {
                    crate::device::Target::Cpu { combo, .. } => wl.combo_load(combo),
                    crate::device::Target::Gpu { .. } => wl.max_load(),
                };
                (wl.name.clone(), wl.batch, load, wl.gpu_share)
            }
            None => ("-".to_string(), 1, 0.0, 1.0),
        };
        rows.push(ScenarioRow {
            scenario: sc.id.clone(),
            workload,
            batch,
            load,
            gpu_share,
            rmspe,
            mape,
            n_test: test_g.len(),
        });
    }
    EvalReport { rows, bound: RMSPE_BOUND }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_covers_every_regime_and_stays_in_bound() {
        let cfg = EvalConfig { seed: 11, n_train: 6, n_test: 3, runs: 1, socs: 1 };
        let report = run(&cfg);
        let presets = crate::workload::builtin_presets().len();
        // One SoC: (CPU + GPU) × (isolated + every preset).
        assert_eq!(report.rows.len(), 2 * (1 + presets));
        assert_eq!(report.contended_rows(), 2 * presets);
        assert!(report.rows.iter().any(|r| r.workload == "-"));
        assert!(report.rows.iter().any(|r| r.scenario.contains('@')));
        // Contended ids carry their workload suffix.
        for r in &report.rows {
            if r.workload != "-" {
                assert!(r.scenario.ends_with(&format!("@{}", r.workload)), "{}", r.scenario);
            }
        }
        // The deterministic cost model trains clean predictors in every
        // regime — this is the tripwire the artifact exists for.
        assert!(report.ok(), "max_rmspe={}", report.max_rmspe());
        assert!(report.max_rmspe() < RMSPE_BOUND);
    }

    #[test]
    fn artifact_json_roundtrips_with_summary_fields() {
        let cfg = EvalConfig { seed: 5, n_train: 5, n_test: 3, runs: 1, socs: 1 };
        let report = run(&cfg);
        let doc = Json::parse(&report.to_json().to_string()).expect("valid JSON");
        assert_eq!(doc.req_str("format").unwrap(), EVAL_FORMAT);
        assert_eq!(doc.req_usize("version").unwrap(), EVAL_VERSION as usize);
        assert_eq!(doc.req_usize("scenarios").unwrap(), report.rows.len());
        assert_eq!(doc.req_usize("contended").unwrap(), report.contended_rows());
        assert_eq!(doc.get("ok"), Some(&Json::Bool(report.ok())));
        let rows = doc.req("rows").unwrap().as_arr().expect("rows array");
        assert_eq!(rows.len(), report.rows.len());
        for r in rows {
            assert!(r.req_f64("rmspe").unwrap().is_finite());
            assert!(r.req_usize("batch").unwrap() >= 1);
        }
    }
}
