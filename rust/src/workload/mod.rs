//! Contention- and batch-aware workload descriptors: the interference
//! dimension of the scenario universe.
//!
//! The paper profiles one model running **alone at batch 1**. Real edge
//! serving co-locates workloads and batches requests — RaPP conditions its
//! predictor on batch size and GPU quota share, and MAPLE-Edge leans on
//! runtime state for the same reason. A [`WorkloadSpec`] makes those axes
//! *data*, exactly like `device::spec` made SoCs data: a versioned JSON
//! document (batch size, per-cluster co-runner load, GPU quota share) that
//! validates standalone, registers into a `scenario::Registry`
//! cross-product ([`Registry::register_workload`]), qualifies scenario ids
//! as `BASE@WORKLOAD`, and rides inside predictor bundles so a contended
//! bundle serves anywhere.
//!
//! The cost model itself stays in `device::cost`; this module owns the
//! deterministic multipliers it applies:
//! - **CPU contention**: co-runner load `l` on the clusters a combo uses
//!   inflates streamed-byte time by `1 + 0.9·l` (memory-bandwidth
//!   pressure — the dominant interference channel on mobile SoCs) and
//!   compute time by `1 + 0.25·l` (preemption slices).
//! - **GPU quota**: a time-slice share `s` stretches busy time by `1/s`;
//!   dispatch overhead is paid regardless of who holds the GPU.
//! - **Batch scaling**: a batch of `b` items costs
//!   `b − 0.15·(b−1)` × the per-item variable work (sub-linear: cache
//!   reuse and amortized im2col/pack steps), while per-op fixed overheads
//!   are paid **once per batch**. Scenario latency under a workload is
//!   whole-batch latency, so `ms(b) ∈ [ms(1), b·ms(1)]` and per-item
//!   amortized cost never increases with `b` — `tests/properties.rs`
//!   asserts all three across sampled SoCs.
//!
//! An absent workload (`Scenario.workload == None`) means the paper's
//! isolated/batch-1 regime, and every isolated code path is bit-identical
//! to the pre-workload tree: the cost functions multiply by exactly `1.0`
//! (an IEEE no-op) and RNG label derivation only extends when a workload
//! is present.

pub mod eval;

use crate::device::CoreCombo;
use crate::scenario::Scenario;
use crate::util::Json;
use std::sync::OnceLock;

/// Format tag of a workload-spec JSON document.
pub const WORKLOAD_FORMAT: &str = "edgelat.workload_spec";
/// Current workload-spec schema version.
pub const WORKLOAD_VERSION: u64 = 1;

/// Largest accepted batch size (power of two; matches the cluster core cap).
pub const MAX_BATCH: usize = 64;

/// Memory-bandwidth inflation per unit of co-runner load: a saturating
/// co-runner nearly doubles streamed-byte cost.
pub const CPU_MEM_CONTENTION: f64 = 0.9;
/// Compute-time inflation per unit of co-runner load (preemption slices;
/// much milder than the bandwidth channel).
pub const CPU_COMPUTE_CONTENTION: f64 = 0.25;
/// Fraction of per-item variable work amortized away at batch > 1.
pub const BATCH_AMORTIZATION: f64 = 0.15;

/// Multiplier on CPU compute time under co-runner load `l ∈ [0, 1]`.
pub fn cpu_compute_mult(load: f64) -> f64 {
    1.0 + CPU_COMPUTE_CONTENTION * load
}

/// Multiplier on CPU streamed-byte (memory) time under co-runner load.
pub fn cpu_mem_mult(load: f64) -> f64 {
    1.0 + CPU_MEM_CONTENTION * load
}

/// Multiplier on GPU busy time under a quota share `s ∈ (0, 1]`.
pub fn gpu_quota_mult(share: f64) -> f64 {
    1.0 / share
}

/// Whole-batch multiplier on per-item variable work: `b − 0.15·(b−1)`.
/// Exactly 1 at batch 1; strictly increasing; sub-linear (`≤ b`), and the
/// per-item amortized ratio `mult(b)/b` never increases with `b`.
pub fn batch_work_mult(batch: usize) -> f64 {
    let b = batch as f64;
    b - BATCH_AMORTIZATION * (b - 1.0)
}

/// A versioned workload descriptor: one co-location + batching regime.
///
/// `cpu_load[i]` is the co-runner load fraction on cluster `i`; a spec may
/// list fewer entries than a SoC has clusters, in which case the last
/// entry broadcasts ([`effective_load`](Self::effective_load)) — workload
/// specs are device-portable, like quota shares in a deployment manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Registry name; qualifies scenario ids as `BASE@name`.
    pub name: String,
    /// Batch size: a power of two in `1..=MAX_BATCH`.
    pub batch: usize,
    /// Per-cluster co-runner load fractions, each in `[0, 1]`.
    pub cpu_load: Vec<f64>,
    /// GPU time-slice/quota share in `(0, 1]` (1 = exclusive GPU).
    pub gpu_share: f64,
}

impl WorkloadSpec {
    /// The isolated/batch-1 regime as an explicit spec (useful as a
    /// baseline row in sweeps; scenarios use `workload: None` for it).
    pub fn isolated(name: &str) -> WorkloadSpec {
        WorkloadSpec { name: name.into(), batch: 1, cpu_load: vec![0.0], gpu_share: 1.0 }
    }

    /// Semantic validation, mirroring `device::spec::validate_soc`.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("workload name is empty".into());
        }
        for bad in ['/', ',', '#', '@'] {
            if self.name.contains(bad) {
                return Err(format!(
                    "workload name '{}' contains '{bad}' (reserved by scenario ids and CLI lists)",
                    self.name
                ));
            }
        }
        if self.batch == 0 || self.batch > MAX_BATCH || !self.batch.is_power_of_two() {
            return Err(format!(
                "workload '{}': batch must be a power of two in 1..={MAX_BATCH}, got {}",
                self.name, self.batch
            ));
        }
        if self.cpu_load.is_empty() {
            return Err(format!("workload '{}': cpu_load is empty", self.name));
        }
        for (i, &l) in self.cpu_load.iter().enumerate() {
            if !l.is_finite() || !(0.0..=1.0).contains(&l) {
                return Err(format!(
                    "workload '{}': cpu_load[{i}] must be in [0, 1], got {l}",
                    self.name
                ));
            }
        }
        if !self.gpu_share.is_finite() || self.gpu_share <= 0.0 || self.gpu_share > 1.0 {
            return Err(format!(
                "workload '{}': gpu_share must be in (0, 1], got {}",
                self.name, self.gpu_share
            ));
        }
        Ok(())
    }

    /// Co-runner load on cluster `i`; the last listed entry broadcasts to
    /// any further clusters.
    pub fn effective_load(&self, cluster: usize) -> f64 {
        self.cpu_load[cluster.min(self.cpu_load.len() - 1)]
    }

    /// The load a CPU core combo experiences: the max effective load over
    /// the clusters it actually uses (the slowest-core roofline means the
    /// most-contended used cluster bounds the op).
    pub fn combo_load(&self, combo: &CoreCombo) -> f64 {
        combo
            .counts
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .map(|(i, _)| self.effective_load(i))
            .fold(0.0, f64::max)
    }

    /// The max effective load over every listed cluster (the GPU feature
    /// column — co-runners contend for shared DRAM regardless of cluster).
    pub fn max_load(&self) -> f64 {
        self.cpu_load.iter().copied().fold(0.0, f64::max)
    }

    /// Whole-batch multiplier on per-item variable work for this spec.
    pub fn batch_work_mult(&self) -> f64 {
        batch_work_mult(self.batch)
    }

    /// Whether this spec perturbs anything relative to isolated/batch-1.
    pub fn is_contended(&self) -> bool {
        self.batch > 1 || self.max_load() > 0.0 || self.gpu_share < 1.0
    }

    /// Serialize as a versioned JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(WORKLOAD_FORMAT)),
            ("version", Json::num(WORKLOAD_VERSION as f64)),
            ("name", Json::str(self.name.clone())),
            ("batch", Json::num(self.batch as f64)),
            ("cpu_load", Json::from_f64s(&self.cpu_load)),
            ("gpu_share", Json::num(self.gpu_share)),
        ])
    }

    /// Parse + validate a workload-spec JSON document.
    pub fn from_json(j: &Json) -> Result<WorkloadSpec, String> {
        let format = j.req_str("format")?;
        if format != WORKLOAD_FORMAT {
            return Err(format!("format is '{format}', want '{WORKLOAD_FORMAT}'"));
        }
        let version = j.req_usize("version")? as u64;
        if version != WORKLOAD_VERSION {
            return Err(format!(
                "workload spec version {version} not supported (current {WORKLOAD_VERSION})"
            ));
        }
        let spec = WorkloadSpec {
            name: j.req_str("name")?.to_string(),
            batch: j.req_usize("batch")?,
            cpu_load: j.req_f64_arr("cpu_load")?,
            gpu_share: j.req_f64("gpu_share")?,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Feature columns a workload contributes to a lowered-plan row:
/// `[batch, co-runner load, gpu share]` — `None` for isolated scenarios,
/// so existing bundles' feature widths are untouched. The load column is
/// the combo's experienced load on CPU targets and the global max on the
/// GPU; the share column is 1 on CPU (quota does not throttle CPU cores).
/// Shared by `plan::lower` and `framework::deduce_units`, which must stay
/// bit-identical.
pub fn feature_cols(sc: &Scenario) -> Option<[f64; 3]> {
    use crate::device::Target;
    sc.workload.as_ref().map(|wl| match &sc.target {
        Target::Cpu { combo, .. } => [wl.batch as f64, wl.combo_load(combo), 1.0],
        Target::Gpu { .. } => [wl.batch as f64, wl.max_load(), wl.gpu_share],
    })
}

/// The committed workload presets (one per axis plus a mixed regime) —
/// the workload analogue of `device::builtin_specs`. Parsed once per
/// process; **not** auto-registered, so the builtin registry still
/// enumerates exactly the paper's 72 isolated scenarios.
pub fn builtin_presets() -> &'static [WorkloadSpec] {
    static PRESETS: OnceLock<Vec<WorkloadSpec>> = OnceLock::new();
    PRESETS.get_or_init(|| {
        [
            include_str!("presets/batch4.json"),
            include_str!("presets/corun50.json"),
            include_str!("presets/burst8.json"),
        ]
        .iter()
        .map(|text| {
            let j = Json::parse(text).expect("builtin workload preset parses");
            WorkloadSpec::from_json(&j).expect("builtin workload preset validates")
        })
        .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DataRep;

    fn corun(load: f64, share: f64, batch: usize) -> WorkloadSpec {
        WorkloadSpec { name: "t".into(), batch, cpu_load: vec![load], gpu_share: share }
    }

    #[test]
    fn builtin_presets_validate_and_cover_both_axes() {
        let ps = builtin_presets();
        assert_eq!(ps.len(), 3);
        let mut names: Vec<&str> = ps.iter().map(|p| p.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 3, "preset names must be unique");
        assert!(ps.iter().all(|p| p.is_contended()));
        assert!(ps.iter().any(|p| p.batch > 1), "a batch axis preset");
        assert!(ps.iter().any(|p| p.max_load() > 0.0), "a contention axis preset");
    }

    #[test]
    fn json_roundtrip_is_exact() {
        for p in builtin_presets() {
            let back = WorkloadSpec::from_json(&p.to_json()).unwrap();
            assert_eq!(&back, p);
            // Canonical text round-trips byte-identically too.
            assert_eq!(back.to_json().to_string(), p.to_json().to_string());
        }
    }

    #[test]
    fn invalid_specs_are_rejected_with_reasons() {
        let cases: Vec<(WorkloadSpec, &str)> = vec![
            (WorkloadSpec { name: "".into(), ..corun(0.0, 1.0, 1) }, "name is empty"),
            (WorkloadSpec { name: "a@b".into(), ..corun(0.0, 1.0, 1) }, "'@'"),
            (WorkloadSpec { name: "a/b".into(), ..corun(0.0, 1.0, 1) }, "'/'"),
            (corun(0.0, 1.0, 3), "power of two"),
            (corun(0.0, 1.0, 0), "power of two"),
            (corun(0.0, 1.0, 128), "power of two"),
            (corun(1.5, 1.0, 1), "cpu_load[0]"),
            (corun(f64::NAN, 1.0, 1), "cpu_load[0]"),
            (corun(0.5, 0.0, 1), "gpu_share"),
            (corun(0.5, 1.5, 1), "gpu_share"),
            (WorkloadSpec { cpu_load: vec![], ..corun(0.0, 1.0, 1) }, "cpu_load is empty"),
        ];
        for (spec, want) in cases {
            let err = spec.validate().unwrap_err();
            assert!(err.contains(want), "want '{want}' in '{err}'");
        }
    }

    #[test]
    fn multipliers_anchor_at_the_isolated_point() {
        assert_eq!(cpu_compute_mult(0.0), 1.0);
        assert_eq!(cpu_mem_mult(0.0), 1.0);
        assert_eq!(gpu_quota_mult(1.0), 1.0);
        assert_eq!(batch_work_mult(1), 1.0);
    }

    #[test]
    fn batch_mult_is_sublinear_and_amortizing() {
        let mut prev = batch_work_mult(1);
        let mut prev_per_item = prev;
        for b in [2usize, 4, 8, 16, 32, 64] {
            let m = batch_work_mult(b);
            assert!(m > prev, "whole-batch work must grow with batch");
            assert!(m < b as f64, "batch {b}: sub-linear, got {m}");
            assert!(m >= 1.0);
            let per_item = m / b as f64;
            assert!(per_item <= prev_per_item, "per-item cost must amortize");
            prev = m;
            prev_per_item = per_item;
        }
    }

    #[test]
    fn effective_load_broadcasts_the_last_cluster() {
        let wl =
            WorkloadSpec { name: "w".into(), batch: 1, cpu_load: vec![0.2, 0.7], gpu_share: 1.0 };
        assert_eq!(wl.effective_load(0), 0.2);
        assert_eq!(wl.effective_load(1), 0.7);
        assert_eq!(wl.effective_load(5), 0.7, "broadcasts past the listed clusters");
        assert_eq!(wl.max_load(), 0.7);
    }

    #[test]
    fn combo_load_is_max_over_used_clusters() {
        let wl =
            WorkloadSpec { name: "w".into(), batch: 1, cpu_load: vec![0.8, 0.1, 0.3], gpu_share: 1.0 };
        assert_eq!(wl.combo_load(&CoreCombo::new(vec![0, 1, 0])), 0.1);
        assert_eq!(wl.combo_load(&CoreCombo::new(vec![1, 0, 2])), 0.8);
        assert_eq!(wl.combo_load(&CoreCombo::new(vec![0, 1, 1])), 0.3);
        assert_eq!(wl.combo_load(&CoreCombo::new(vec![0, 0, 0])), 0.0);
    }

    #[test]
    fn feature_cols_absent_for_isolated_scenarios() {
        let reg = crate::scenario::Registry::builtin();
        for sc in reg.all() {
            assert!(feature_cols(sc).is_none(), "{}", sc.id);
        }
    }

    #[test]
    fn feature_cols_encode_target_specific_axes() {
        let soc = crate::device::soc_by_name("Snapdragon855").unwrap();
        let wl = std::sync::Arc::new(WorkloadSpec {
            name: "w".into(),
            batch: 4,
            cpu_load: vec![0.5, 0.25, 0.0],
            gpu_share: 0.5,
        });
        let cpu = Scenario::cpu(&soc, vec![0, 0, 4], DataRep::Fp32)
            .unwrap()
            .with_workload(wl.clone());
        assert_eq!(feature_cols(&cpu), Some([4.0, 0.0, 1.0]));
        let gpu = Scenario::gpu(&soc).with_workload(wl);
        assert_eq!(feature_cols(&gpu), Some([4.0, 0.5, 0.5]));
    }

    #[test]
    fn isolated_spec_is_not_contended() {
        let iso = WorkloadSpec::isolated("iso");
        iso.validate().unwrap();
        assert!(!iso.is_contended());
        assert!(corun(0.0, 1.0, 2).is_contended());
        assert!(corun(0.1, 1.0, 1).is_contended());
        assert!(corun(0.0, 0.9, 1).is_contended());
    }
}
