//! Measurement/serving scenarios over an **open** device universe.
//!
//! A scenario is one (SoC, target) pair — a CPU core combination in fp32 or
//! int8, or the GPU. The paper studies 72 of them across 4 SoCs (Section
//! 4.3); this module no longer hard-codes that set. The single source of
//! scenario truth is the [`Registry`]: the four Table 1 devices are
//! committed spec data (`device/specs/*.json`) registered into
//! [`Registry::builtin`], and any new device is a spec file registered at
//! runtime ([`Registry::load_spec_json`], `--device-spec` on the CLI).
//!
//! Construction is fallible ([`ScenarioError`]) — an invalid core combo or
//! an unknown SoC is a typed error surfaced to the caller, never a library
//! panic. The free functions at the bottom are thin compatibility shims
//! over the builtin singleton kept so existing figure/test code compiles;
//! new code should hold a `Registry` (or `&'static Registry`).

mod registry;

pub use registry::Registry;

use crate::device::{CoreCombo, DataRep, Soc, Target};
use crate::tflite::CompileOptions;
use crate::workload::WorkloadSpec;
use std::fmt;
use std::sync::Arc;

/// Typed errors for scenario construction and registry operations.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioError {
    /// No registered SoC with this name.
    UnknownSoc(String),
    /// No registered scenario with this id.
    UnknownScenario(String),
    /// A SoC with this name is already registered.
    DuplicateSoc(String),
    /// A workload with this name is already registered.
    DuplicateWorkload(String),
    /// A core combination this SoC cannot realize.
    InvalidCombo { soc: String, detail: String },
    /// A malformed or invalid device-spec document.
    Spec(String),
    /// A malformed or invalid workload-spec document.
    Workload(String),
}

impl fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScenarioError::UnknownSoc(name) => {
                write!(f, "unknown SoC '{name}' (see `edgelat devices list`)")
            }
            ScenarioError::UnknownScenario(id) => {
                write!(f, "unknown scenario '{id}' (see `edgelat list scenarios`)")
            }
            ScenarioError::DuplicateSoc(name) => {
                write!(f, "SoC '{name}' is already registered")
            }
            ScenarioError::DuplicateWorkload(name) => {
                write!(f, "workload '{name}' is already registered")
            }
            ScenarioError::InvalidCombo { soc, detail } => {
                write!(f, "invalid core combo on {soc}: {detail}")
            }
            ScenarioError::Spec(e) => write!(f, "device spec error: {e}"),
            ScenarioError::Workload(e) => write!(f, "workload spec error: {e}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

/// One profiling/prediction scenario on a specific SoC.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub soc: Soc,
    pub target: Target,
    /// Stable id like "Snapdragon855/cpu/1L+3M/fp32" or "HelioP35/gpu";
    /// workload-qualified scenarios append `@WORKLOAD`.
    pub id: String,
    /// The co-location/batching regime, `None` for the paper's isolated
    /// batch-1 regime (every builtin scenario).
    pub workload: Option<Arc<WorkloadSpec>>,
}

impl Scenario {
    /// A CPU scenario, validating the combo against the SoC's clusters.
    pub fn cpu(soc: &Soc, counts: Vec<usize>, rep: DataRep) -> Result<Scenario, ScenarioError> {
        let combo = CoreCombo::new(counts);
        combo.validate(soc).map_err(|detail| ScenarioError::InvalidCombo {
            soc: soc.name.clone(),
            detail,
        })?;
        let id = format!("{}/cpu/{}/{}", soc.name, combo.label(soc), rep.name());
        Ok(Scenario { soc: soc.clone(), target: Target::Cpu { combo, rep }, id, workload: None })
    }

    pub fn gpu(soc: &Soc) -> Scenario {
        Scenario {
            soc: soc.clone(),
            target: Target::Gpu { options: CompileOptions::default() },
            id: format!("{}/gpu", soc.name),
            workload: None,
        }
    }

    /// The same (SoC, target) under a workload: the id gains an
    /// `@WORKLOAD` suffix and the cost model applies the workload's
    /// contention/batch multipliers. The spec must already be validated
    /// (the registry and bundle loaders validate before qualifying).
    pub fn with_workload(&self, workload: Arc<WorkloadSpec>) -> Scenario {
        debug_assert!(self.workload.is_none(), "{}: already workload-qualified", self.id);
        Scenario {
            soc: self.soc.clone(),
            target: self.target.clone(),
            id: format!("{}@{}", self.id, workload.name),
            workload: Some(workload),
        }
    }

    /// The id without any `@WORKLOAD` qualifier (the isolated base id).
    pub fn base_id(&self) -> &str {
        match self.workload {
            Some(_) => self.id.rsplit_once('@').map(|(base, _)| base).unwrap_or(&self.id),
            None => &self.id,
        }
    }

    pub fn is_gpu(&self) -> bool {
        matches!(self.target, Target::Gpu { .. })
    }

    /// The combo label ("1L+3M") for CPU scenarios, "gpu" otherwise.
    pub fn combo_label(&self) -> String {
        match &self.target {
            Target::Cpu { combo, .. } => combo.label(&self.soc),
            Target::Gpu { .. } => "gpu".into(),
        }
    }
}

/// Per-SoC CPU core combinations studied (Figs 2, 15, 23). Compat shim over
/// [`Registry::builtin`] — runtime-registered SoCs resolve through their own
/// registry's [`Registry::combos`].
pub fn cpu_combos(soc: &Soc) -> Result<Vec<Vec<usize>>, ScenarioError> {
    Registry::builtin().combos(&soc.name)
}

/// All 72 scenarios across the 4 builtin platforms. Compat shim (clones);
/// prefer [`Registry::all`], which hands out `Arc<Scenario>`.
pub fn all_scenarios() -> Vec<Scenario> {
    Registry::builtin().all().iter().map(|s| (**s).clone()).collect()
}

/// The "default" NAS scenarios the headline results use: one large CPU core
/// (fp32) per platform plus each GPU (Fig 14, Tables 4/5). Compat shim over
/// [`Registry::headline`].
pub fn headline_scenarios() -> Vec<Scenario> {
    Registry::builtin().headline()
}

/// Find a builtin scenario by id. Hands out the registry's shared
/// `Arc<Scenario>` — no `Scenario` (SoC + clusters) clone per lookup.
pub fn by_id(id: &str) -> Option<Arc<Scenario>> {
    Registry::builtin().by_id(id)
}

/// Build a single-large-core fp32 scenario for a builtin SoC by name.
pub fn one_large_core(soc_name: &str) -> Result<Scenario, ScenarioError> {
    Registry::builtin().one_large_core(soc_name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_72_scenarios() {
        let all = all_scenarios();
        assert_eq!(all.len(), 72, "paper: 72 scenarios across 4 platforms");
        let gpus = all.iter().filter(|s| s.is_gpu()).count();
        assert_eq!(gpus, 4);
    }

    #[test]
    fn ids_unique() {
        let all = all_scenarios();
        let mut ids: Vec<&str> = all.iter().map(|s| s.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 72);
    }

    #[test]
    fn all_combos_valid() {
        for soc in crate::device::socs() {
            for c in cpu_combos(&soc).unwrap() {
                CoreCombo::new(c).validate(&soc).unwrap();
            }
        }
    }

    #[test]
    fn headline_is_8() {
        assert_eq!(headline_scenarios().len(), 8);
    }

    #[test]
    fn by_id_roundtrip() {
        for s in all_scenarios() {
            let found = by_id(&s.id).unwrap_or_else(|| panic!("{}", s.id));
            assert_eq!(found.id, s.id);
            assert_eq!(found.soc.name, s.soc.name);
        }
    }

    #[test]
    fn by_id_unknown_is_none() {
        assert!(by_id("NoSuchSoc/cpu/1L/fp32").is_none());
        assert!(by_id("").is_none());
    }

    #[test]
    fn by_id_shares_one_arc_per_scenario() {
        let a = by_id("HelioP35/gpu").unwrap();
        let b = by_id("HelioP35/gpu").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "lookups must not clone the scenario");
    }

    #[test]
    fn workload_qualification_suffixes_the_id() {
        let base = by_id("HelioP35/gpu").unwrap();
        assert_eq!(base.base_id(), "HelioP35/gpu");
        let wl = Arc::new(crate::workload::builtin_presets()[0].clone());
        let q = base.with_workload(wl.clone());
        assert_eq!(q.id, format!("HelioP35/gpu@{}", wl.name));
        assert_eq!(q.base_id(), "HelioP35/gpu");
        assert_eq!(q.soc, base.soc);
        assert_eq!(q.target, base.target);
        assert_eq!(q.workload.as_deref(), Some(&*wl));
        // Structural equality distinguishes workload regimes.
        assert_ne!(q, (*base).clone());
    }

    #[test]
    fn invalid_inputs_are_typed_errors_not_panics() {
        let soc = crate::device::soc_by_name("Snapdragon855").unwrap();
        // Too many prime cores: InvalidCombo naming the SoC.
        let err = Scenario::cpu(&soc, vec![2, 0, 0], DataRep::Fp32).unwrap_err();
        assert!(matches!(err, ScenarioError::InvalidCombo { .. }), "{err}");
        assert!(err.to_string().contains("Snapdragon855"), "{err}");
        // Wrong arity and the empty combo too.
        assert!(Scenario::cpu(&soc, vec![1, 0], DataRep::Fp32).is_err());
        assert!(Scenario::cpu(&soc, vec![0, 0, 0], DataRep::Int8).is_err());
        // Unknown SoC name: UnknownSoc, not a panic.
        let err = one_large_core("NotASoc").unwrap_err();
        assert_eq!(err, ScenarioError::UnknownSoc("NotASoc".into()));
        let fake = Soc { name: "NotASoc".into(), ..soc };
        let err = cpu_combos(&fake).unwrap_err();
        assert_eq!(err, ScenarioError::UnknownSoc("NotASoc".into()));
    }
}
