//! The 72 measurement scenarios of Section 4.3: for each of the 4 SoCs, a
//! set of CPU core combinations x {fp32, int8} plus the GPU — 34 CPU combos
//! x 2 representations + 4 GPUs = 72.

use crate::device::{soc_by_name, CoreCombo, DataRep, Soc, Target};
use crate::tflite::CompileOptions;

/// One profiling/prediction scenario on a specific SoC.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub soc: Soc,
    pub target: Target,
    /// Stable id like "Snapdragon855/cpu/1L+3M/fp32" or "HelioP35/gpu".
    pub id: String,
}

impl Scenario {
    pub fn cpu(soc: &Soc, counts: Vec<usize>, rep: DataRep) -> Scenario {
        let combo = CoreCombo::new(counts);
        combo.validate(soc).expect("invalid combo");
        let id = format!("{}/cpu/{}/{}", soc.name, combo.label(soc), rep.name());
        Scenario { soc: soc.clone(), target: Target::Cpu { combo, rep }, id }
    }

    pub fn gpu(soc: &Soc) -> Scenario {
        Scenario {
            soc: soc.clone(),
            target: Target::Gpu { options: CompileOptions::default() },
            id: format!("{}/gpu", soc.name),
        }
    }

    pub fn is_gpu(&self) -> bool {
        matches!(self.target, Target::Gpu { .. })
    }

    /// The combo label ("1L+3M") for CPU scenarios, "gpu" otherwise.
    pub fn combo_label(&self) -> String {
        match &self.target {
            Target::Cpu { combo, .. } => combo.label(&self.soc),
            Target::Gpu { .. } => "gpu".into(),
        }
    }
}

/// Per-SoC CPU core combinations studied (Figs 2, 15, 23).
pub fn cpu_combos(soc: &Soc) -> Vec<Vec<usize>> {
    match soc.name {
        // L=1 prime, M=3 gold, S=4 silver
        "Snapdragon855" => vec![
            vec![1, 0, 0],
            vec![0, 1, 0],
            vec![0, 2, 0],
            vec![0, 3, 0],
            vec![0, 0, 1],
            vec![0, 0, 2],
            vec![0, 0, 4],
            vec![1, 1, 0],
            vec![1, 3, 0],
            vec![0, 1, 1],
        ],
        // L=2 gold, S=6 silver
        "Snapdragon710" => vec![
            vec![1, 0],
            vec![2, 0],
            vec![0, 1],
            vec![0, 2],
            vec![0, 4],
            vec![0, 6],
            vec![1, 1],
        ],
        // L=2 M4, M=2 A75, S=4 A55
        "Exynos9820" => vec![
            vec![1, 0, 0],
            vec![2, 0, 0],
            vec![0, 1, 0],
            vec![0, 2, 0],
            vec![0, 0, 1],
            vec![0, 0, 2],
            vec![0, 0, 4],
            vec![1, 0, 1],
            vec![1, 2, 0],
            vec![2, 2, 4],
        ],
        // L=4 A53@2.3, S=4 A53@1.8
        "HelioP35" => vec![
            vec![1, 0],
            vec![2, 0],
            vec![4, 0],
            vec![0, 1],
            vec![0, 2],
            vec![0, 4],
            vec![4, 4],
        ],
        other => panic!("unknown soc {other}"),
    }
}

/// All 72 scenarios across the 4 platforms.
pub fn all_scenarios() -> Vec<Scenario> {
    let mut v = Vec::new();
    for soc in crate::device::socs() {
        for counts in cpu_combos(&soc) {
            for rep in [DataRep::Fp32, DataRep::Int8] {
                v.push(Scenario::cpu(&soc, counts.clone(), rep));
            }
        }
        v.push(Scenario::gpu(&soc));
    }
    v
}

/// The "default" NAS scenarios the headline results use: one large CPU core
/// (fp32) per platform plus each GPU (Fig 14, Tables 4/5).
pub fn headline_scenarios() -> Vec<Scenario> {
    let mut v = Vec::new();
    for soc in crate::device::socs() {
        let mut counts = vec![0; soc.clusters.len()];
        counts[0] = 1;
        v.push(Scenario::cpu(&soc, counts, DataRep::Fp32));
        v.push(Scenario::gpu(&soc));
    }
    v
}

/// Find a scenario by id.
///
/// Backed by a lazily-built index: the old implementation rebuilt all 72
/// scenarios per lookup, which made multi-bundle `EngineBuilder::build`
/// (one `by_id` call per bundle) and CLI flag parsing quadratic.
pub fn by_id(id: &str) -> Option<Scenario> {
    let (all, by_id) = scenario_index();
    by_id.get(id).map(|&i| all[i].clone())
}

fn scenario_index(
) -> &'static (Vec<Scenario>, std::collections::HashMap<String, usize>) {
    static INDEX: std::sync::OnceLock<(
        Vec<Scenario>,
        std::collections::HashMap<String, usize>,
    )> = std::sync::OnceLock::new();
    INDEX.get_or_init(|| {
        let all = all_scenarios();
        let by_id = all.iter().enumerate().map(|(i, s)| (s.id.clone(), i)).collect();
        (all, by_id)
    })
}

/// Build a single-large-core fp32 scenario for a SoC by name.
pub fn one_large_core(soc_name: &str) -> Scenario {
    let soc = soc_by_name(soc_name).expect("unknown soc");
    let mut counts = vec![0; soc.clusters.len()];
    counts[0] = 1;
    Scenario::cpu(&soc, counts, DataRep::Fp32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_72_scenarios() {
        let all = all_scenarios();
        assert_eq!(all.len(), 72, "paper: 72 scenarios across 4 platforms");
        let gpus = all.iter().filter(|s| s.is_gpu()).count();
        assert_eq!(gpus, 4);
    }

    #[test]
    fn ids_unique() {
        let all = all_scenarios();
        let mut ids: Vec<&str> = all.iter().map(|s| s.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 72);
    }

    #[test]
    fn all_combos_valid() {
        for soc in crate::device::socs() {
            for c in cpu_combos(&soc) {
                CoreCombo::new(c).validate(&soc).unwrap();
            }
        }
    }

    #[test]
    fn headline_is_8() {
        assert_eq!(headline_scenarios().len(), 8);
    }

    #[test]
    fn by_id_roundtrip() {
        for s in all_scenarios() {
            let found = by_id(&s.id).unwrap_or_else(|| panic!("{}", s.id));
            assert_eq!(found.id, s.id);
            assert_eq!(found.soc.name, s.soc.name);
        }
    }

    #[test]
    fn by_id_unknown_is_none() {
        assert!(by_id("NoSuchSoc/cpu/1L/fp32").is_none());
        assert!(by_id("").is_none());
    }
}
