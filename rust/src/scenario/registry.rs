//! The [`Registry`]: the single source of scenario truth over an open,
//! data-driven device universe.
//!
//! A registry owns a set of [`SocSpec`]s and the scenarios they yield —
//! for each spec, every studied core combo in both data representations
//! plus the GPU, in spec order (the builtin registry reproduces the
//! paper's 72 scenarios bit-identically from the committed spec files).
//! Scenarios are stored once behind `Arc`, so [`by_id`](Registry::by_id)
//! lookups hand out shared pointers instead of cloning a `Soc` + cluster
//! table per call.

use crate::device::{builtin_specs, DataRep, Soc, SocSpec};
use crate::scenario::{Scenario, ScenarioError};
use crate::util::Json;
use crate::workload::WorkloadSpec;
use std::collections::HashMap;
use std::sync::Arc;

/// An ordered set of registered SoCs, workloads, and the scenario
/// cross-product they yield: every SoC's isolated scenarios plus one
/// `BASE@WORKLOAD` qualification per registered workload.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    specs: Vec<Arc<SocSpec>>,
    workloads: Vec<Arc<WorkloadSpec>>,
    scenarios: Vec<Arc<Scenario>>,
    index: HashMap<String, usize>,
}

impl Registry {
    /// An empty registry (no devices).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A registry holding the four builtin Table 1 SoCs.
    pub fn with_builtin() -> Registry {
        let mut r = Registry::new();
        for spec in builtin_specs() {
            r.register_soc(spec.clone()).expect("builtin specs register cleanly");
        }
        r
    }

    /// The shared builtin singleton, built once per process — what the
    /// compatibility shims in `scenario` resolve against.
    pub fn builtin() -> &'static Registry {
        static REG: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
        REG.get_or_init(Registry::with_builtin)
    }

    /// Register a SoC: validate the spec, then materialize its scenarios
    /// (per combo: fp32 then int8; then the GPU — the Section 4.3
    /// enumeration order), followed by one workload-qualified copy of each
    /// per already-registered workload. Returns the number of scenarios
    /// added.
    pub fn register_soc(&mut self, spec: SocSpec) -> Result<usize, ScenarioError> {
        spec.validate().map_err(ScenarioError::Spec)?;
        if self.spec(&spec.soc.name).is_some() {
            return Err(ScenarioError::DuplicateSoc(spec.soc.name.clone()));
        }
        let mut scenarios = Vec::with_capacity(spec.scenario_count() * (1 + self.workloads.len()));
        for counts in &spec.combos {
            for rep in [DataRep::Fp32, DataRep::Int8] {
                scenarios.push(Scenario::cpu(&spec.soc, counts.clone(), rep)?);
            }
        }
        scenarios.push(Scenario::gpu(&spec.soc));
        let isolated = scenarios.len();
        for wl in &self.workloads {
            for i in 0..isolated {
                scenarios.push(scenarios[i].with_workload(wl.clone()));
            }
        }
        let added = scenarios.len();
        for s in scenarios {
            // Ids cannot collide: the (unique) SoC name prefixes every id,
            // `SocSpec::validate` rejects duplicate combo labels, and '@'
            // is reserved in both SoC and workload names so qualified ids
            // parse unambiguously.
            debug_assert!(!self.index.contains_key(&s.id), "{}", s.id);
            self.index.insert(s.id.clone(), self.scenarios.len());
            self.scenarios.push(Arc::new(s));
        }
        self.specs.push(Arc::new(spec));
        Ok(added)
    }

    /// Register a workload: validate the spec, then qualify every
    /// currently-registered isolated scenario with it (`BASE@NAME`).
    /// Returns the number of scenarios added. Builtin scenario ids never
    /// change — qualification only ever *adds* ids.
    pub fn register_workload(&mut self, wl: WorkloadSpec) -> Result<usize, ScenarioError> {
        wl.validate().map_err(ScenarioError::Workload)?;
        if self.workload(&wl.name).is_some() {
            return Err(ScenarioError::DuplicateWorkload(wl.name.clone()));
        }
        let wl = Arc::new(wl);
        let base: Vec<Arc<Scenario>> =
            self.scenarios.iter().filter(|s| s.workload.is_none()).cloned().collect();
        let added = base.len();
        for s in &base {
            let q = s.with_workload(wl.clone());
            debug_assert!(!self.index.contains_key(&q.id), "{}", q.id);
            self.index.insert(q.id.clone(), self.scenarios.len());
            self.scenarios.push(Arc::new(q));
        }
        self.workloads.push(wl);
        Ok(added)
    }

    /// Parse, validate, and register a workload-spec JSON document (the
    /// `--workload-spec file.json` path). Returns the workload name.
    pub fn load_workload_json(&mut self, text: &str) -> Result<String, ScenarioError> {
        let j = Json::parse(text).map_err(ScenarioError::Workload)?;
        let wl = WorkloadSpec::from_json(&j).map_err(ScenarioError::Workload)?;
        let name = wl.name.clone();
        self.register_workload(wl)?;
        Ok(name)
    }

    /// Read and register a workload-spec file. Every error, I/O or
    /// semantic, names the file.
    pub fn load_workload_file(
        &mut self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<String, ScenarioError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| ScenarioError::Workload(format!("reading {}: {e}", path.display())))?;
        self.load_workload_json(&text).map_err(|e| {
            let detail = match e {
                ScenarioError::Workload(s) => s,
                other => other.to_string(),
            };
            ScenarioError::Workload(format!("{}: {detail}", path.display()))
        })
    }

    /// Register every committed workload preset
    /// (`workload::builtin_presets`). Returns the number of scenarios
    /// added.
    pub fn register_builtin_workloads(&mut self) -> Result<usize, ScenarioError> {
        let mut added = 0;
        for wl in crate::workload::builtin_presets() {
            added += self.register_workload(wl.clone())?;
        }
        Ok(added)
    }

    /// Parse, validate, and register a device-spec JSON document (the
    /// `--device-spec file.json` path). Returns the registered SoC name.
    pub fn load_spec_json(&mut self, text: &str) -> Result<String, ScenarioError> {
        let j = Json::parse(text).map_err(ScenarioError::Spec)?;
        let spec = SocSpec::from_json(&j).map_err(ScenarioError::Spec)?;
        let name = spec.soc.name.clone();
        self.register_soc(spec)?;
        Ok(name)
    }

    /// Read and register a device-spec file — the one copy of the
    /// file-loading path (CLI `--device-spec`, `devices validate`,
    /// examples). Every error, I/O or semantic, names the file.
    pub fn load_spec_file(
        &mut self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<String, ScenarioError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| ScenarioError::Spec(format!("reading {}: {e}", path.display())))?;
        self.load_spec_json(&text).map_err(|e| {
            // Unwrap the Spec variant so the message is not double-prefixed.
            let detail = match e {
                ScenarioError::Spec(s) => s,
                other => other.to_string(),
            };
            ScenarioError::Spec(format!("{}: {detail}", path.display()))
        })
    }

    /// Registered specs, in registration order.
    pub fn specs(&self) -> &[Arc<SocSpec>] {
        &self.specs
    }

    /// The spec of a registered SoC.
    pub fn spec(&self, soc_name: &str) -> Option<&Arc<SocSpec>> {
        self.specs.iter().find(|s| s.soc.name == soc_name)
    }

    /// Registered SoCs (cloned), in registration order.
    pub fn socs(&self) -> Vec<Soc> {
        self.specs.iter().map(|s| s.soc.clone()).collect()
    }

    /// The studied CPU core combos of a registered SoC.
    pub fn combos(&self, soc_name: &str) -> Result<Vec<Vec<usize>>, ScenarioError> {
        self.spec(soc_name)
            .map(|s| s.combos.clone())
            .ok_or_else(|| ScenarioError::UnknownSoc(soc_name.to_string()))
    }

    /// Every registered scenario, in registration order (for the builtin
    /// registry: the paper's 72).
    pub fn all(&self) -> &[Arc<Scenario>] {
        &self.scenarios
    }

    /// Find a scenario by id — a shared `Arc`, no clone.
    pub fn by_id(&self, id: &str) -> Option<Arc<Scenario>> {
        self.index.get(id).map(|&i| self.scenarios[i].clone())
    }

    /// Like [`by_id`](Self::by_id) but with a typed error naming the id.
    pub fn resolve(&self, id: &str) -> Result<Arc<Scenario>, ScenarioError> {
        self.by_id(id).ok_or_else(|| ScenarioError::UnknownScenario(id.to_string()))
    }

    /// The headline per-device scenarios (Fig 14, Tables 4/5): one large
    /// CPU core (fp32) plus the GPU, for every registered SoC.
    pub fn headline(&self) -> Vec<Scenario> {
        self.specs
            .iter()
            .flat_map(|spec| {
                [
                    self.one_large_core(&spec.soc.name)
                        .expect("spec validated at registration"),
                    Scenario::gpu(&spec.soc),
                ]
            })
            .collect()
    }

    /// A single-large-core fp32 scenario for a registered SoC. Always
    /// constructible: validation guarantees `clusters[0]` has >= 1 core.
    pub fn one_large_core(&self, soc_name: &str) -> Result<Scenario, ScenarioError> {
        let spec = self
            .spec(soc_name)
            .ok_or_else(|| ScenarioError::UnknownSoc(soc_name.to_string()))?;
        let mut counts = vec![0; spec.soc.clusters.len()];
        counts[0] = 1;
        Scenario::cpu(&spec.soc, counts, DataRep::Fp32)
    }

    /// Registered workloads, in registration order.
    pub fn workloads(&self) -> &[Arc<WorkloadSpec>] {
        &self.workloads
    }

    /// The spec of a registered workload.
    pub fn workload(&self, name: &str) -> Option<&Arc<WorkloadSpec>> {
        self.workloads.iter().find(|w| w.name == name)
    }

    /// Number of registered SoCs.
    pub fn soc_count(&self) -> usize {
        self.specs.len()
    }

    /// Number of registered workloads.
    pub fn workload_count(&self) -> usize {
        self.workloads.len()
    }

    /// Number of registered scenarios.
    pub fn scenario_count(&self) -> usize {
        self.scenarios.len()
    }

    /// Number of isolated (workload-free) scenarios.
    pub fn isolated_count(&self) -> usize {
        self.scenarios.iter().filter(|s| s.workload.is_none()).count()
    }

    /// Number of workload-qualified (contended/batched) scenarios.
    pub fn contended_count(&self) -> usize {
        self.scenarios.len() - self.isolated_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn custom_spec() -> SocSpec {
        let mut spec = builtin_specs()[1].clone(); // Snapdragon710 shape
        spec.soc.name = "TestSoc".into();
        spec.soc.platform = "Test Phone".into();
        spec
    }

    #[test]
    fn builtin_registry_matches_the_paper() {
        let r = Registry::builtin();
        assert_eq!(r.soc_count(), 4);
        assert_eq!(r.scenario_count(), 72);
        assert_eq!(r.headline().len(), 8);
        // Ordering reproduces the old hard-coded enumeration.
        assert_eq!(r.all()[0].id, "Snapdragon855/cpu/1L/fp32");
        assert_eq!(r.all()[1].id, "Snapdragon855/cpu/1L/int8");
        assert!(r.all()[20].is_gpu(), "{}", r.all()[20].id);
    }

    #[test]
    fn empty_registry_knows_nothing() {
        let r = Registry::new();
        assert_eq!(r.scenario_count(), 0);
        assert!(r.by_id("Snapdragon855/cpu/1L/fp32").is_none());
        assert_eq!(
            r.one_large_core("Snapdragon855").unwrap_err(),
            ScenarioError::UnknownSoc("Snapdragon855".into())
        );
        assert_eq!(
            r.resolve("X/gpu").unwrap_err(),
            ScenarioError::UnknownScenario("X/gpu".into())
        );
    }

    #[test]
    fn register_custom_soc_extends_the_universe() {
        let mut r = Registry::with_builtin();
        let added = r.register_soc(custom_spec()).unwrap();
        assert_eq!(added, 7 * 2 + 1);
        assert_eq!(r.scenario_count(), 72 + 15);
        assert_eq!(r.soc_count(), 5);
        let sc = r.by_id("TestSoc/cpu/1L/fp32").expect("registered scenario");
        assert_eq!(sc.soc.platform, "Test Phone");
        assert!(r.by_id("TestSoc/gpu").is_some());
        // The builtin singleton is untouched by local registration.
        assert_eq!(Registry::builtin().scenario_count(), 72);
        assert!(Registry::builtin().by_id("TestSoc/gpu").is_none());
    }

    #[test]
    fn duplicate_and_invalid_registrations_rejected() {
        let mut r = Registry::with_builtin();
        let err = r.register_soc(builtin_specs()[0].clone()).unwrap_err();
        assert_eq!(err, ScenarioError::DuplicateSoc("Snapdragon855".into()));
        let mut bad = custom_spec();
        bad.combos.push(vec![99, 0]);
        assert!(matches!(r.register_soc(bad), Err(ScenarioError::Spec(_))));
        // Failed registrations leave the registry unchanged.
        assert_eq!(r.scenario_count(), 72);
    }

    #[test]
    fn workload_registration_builds_the_cross_product() {
        let mut r = Registry::with_builtin();
        assert_eq!(r.workload_count(), 0);
        assert_eq!(r.isolated_count(), 72);
        assert_eq!(r.contended_count(), 0);
        // Three presets qualify every isolated scenario: 72 x (1 + 3).
        let added = r.register_builtin_workloads().unwrap();
        assert_eq!(added, 72 * 3);
        assert_eq!(r.scenario_count(), 288);
        assert!(r.scenario_count() > 200, "the issue's universe floor");
        assert_eq!(r.isolated_count(), 72);
        assert_eq!(r.contended_count(), 216);
        // The first 72 are the untouched builtin ids, in order.
        let builtin = Registry::builtin();
        for (a, b) in r.all().iter().take(72).zip(builtin.all()) {
            assert_eq!(a.id, b.id);
            assert!(a.workload.is_none());
        }
        // Qualified ids resolve and carry their workload.
        let name = &crate::workload::builtin_presets()[0].name;
        let q = r.by_id(&format!("Snapdragon855/cpu/1L/fp32@{name}")).unwrap();
        assert_eq!(q.workload.as_ref().unwrap().name, *name);
        assert_eq!(q.base_id(), "Snapdragon855/cpu/1L/fp32");
        // A SoC registered after the workloads gets its qualified copies.
        let per_soc = 7 * 2 + 1;
        let added = r.register_soc(custom_spec()).unwrap();
        assert_eq!(added, per_soc * 4);
        assert!(r.by_id(&format!("TestSoc/gpu@{name}")).is_some());
        // Duplicate workload names are rejected; registry unchanged.
        let dup = crate::workload::builtin_presets()[0].clone();
        assert_eq!(
            r.register_workload(dup).unwrap_err(),
            ScenarioError::DuplicateWorkload(name.clone())
        );
        assert_eq!(r.scenario_count(), 288 + per_soc * 4);
    }

    #[test]
    fn load_workload_json_roundtrip() {
        let mut r = Registry::with_builtin();
        let text = crate::workload::builtin_presets()[1].to_json().to_string();
        let name = r.load_workload_json(&text).unwrap();
        assert_eq!(name, crate::workload::builtin_presets()[1].name);
        assert_eq!(r.scenario_count(), 144);
        assert!(matches!(r.load_workload_json("{ not json"), Err(ScenarioError::Workload(_))));
        assert!(matches!(
            r.load_workload_json("{\"format\":\"nope\"}"),
            Err(ScenarioError::Workload(_))
        ));
        // File loader names the path in errors.
        let err = r.load_workload_file("/no/such/dir/wl.json").unwrap_err();
        assert!(err.to_string().contains("/no/such/dir/wl.json"), "{err}");
        assert_eq!(err.to_string().matches("workload spec error").count(), 1, "{err}");
    }

    #[test]
    fn load_spec_json_roundtrip() {
        let text = custom_spec().to_json().to_string();
        let mut r = Registry::new();
        let name = r.load_spec_json(&text).unwrap();
        assert_eq!(name, "TestSoc");
        assert_eq!(r.scenario_count(), 15);
        assert!(matches!(
            r.load_spec_json("{ not json"),
            Err(ScenarioError::Spec(_))
        ));
        assert!(matches!(
            r.load_spec_json("{\"format\":\"nope\"}"),
            Err(ScenarioError::Spec(_))
        ));
    }

    #[test]
    fn load_spec_file_names_the_path_in_errors() {
        let mut r = Registry::new();
        let err = r.load_spec_file("/no/such/dir/spec.json").unwrap_err();
        assert!(err.to_string().contains("/no/such/dir/spec.json"), "{err}");
        let path = std::env::temp_dir()
            .join(format!("edgelat_registry_spec_{}.json", std::process::id()));
        std::fs::write(&path, "{}").unwrap();
        let err = r.load_spec_file(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("edgelat_registry_spec"), "{msg}");
        // Not double-prefixed by the Spec variant's Display.
        assert_eq!(msg.matches("device spec error").count(), 1, "{msg}");
        std::fs::write(&path, custom_spec().to_json().to_string()).unwrap();
        assert_eq!(r.load_spec_file(&path).unwrap(), "TestSoc");
        let _ = std::fs::remove_file(&path);
    }
}
