//! The end-to-end latency prediction framework (Section 4).
//!
//! Given a model file and a target scenario, and *without* touching the
//! device: (1) extract the computational graph; (2) for GPUs, deduce the
//! kernels TFLite would execute (fusion + selection, Section 4.1); (3)
//! predict each op/kernel's latency with the per-bucket ML model trained
//! from one-time profiling data (Section 4.2); (4) report
//! `T_overhead + Σ_c f*_c(x_c)`, where `T_overhead` is the mean measured
//! gap between end-to-end latency and the op sum on the training set.
//!
//! [`ScenarioPredictor`] is the training-side view; for the train-once /
//! serialize / load / batch-predict serving path built on top of it, see
//! `crate::engine` ([`deduce_units`] is shared by both).

use crate::features::{bucket_of, conform_conv_kernel_row, cpu_bucket, features, kernel_features};
use crate::graph::Graph;
use crate::predict::{mlp::MlpContext, train, Method, TrainedModel};
use crate::profiler::{bucket_datasets, ModelProfile};
use crate::scenario::Scenario;
use crate::tflite::{compile, fusion, CompileOptions};
use crate::util::{mape, mean};
use crate::device::Target;
use std::collections::BTreeMap;

/// How the predictor handles ML-framework optimizations — the ablations of
/// Section 5.4 (Figs 19, 20).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeductionMode {
    /// Full kernel deduction: fusion + kernel selection (the paper's method).
    Full,
    /// Ignore kernel fusion: predict each graph op as its own kernel.
    NoFusion,
    /// Ignore kernel selection: all convolutions use the Conv2D bucket.
    NoSelection,
}

impl DeductionMode {
    /// Stable name used by the CLI and bundle files.
    pub fn name(&self) -> &'static str {
        match self {
            DeductionMode::Full => "full",
            DeductionMode::NoFusion => "nofusion",
            DeductionMode::NoSelection => "noselection",
        }
    }

    pub fn parse(s: &str) -> Option<DeductionMode> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Some(DeductionMode::Full),
            "nofusion" | "no_fusion" => Some(DeductionMode::NoFusion),
            "noselection" | "no_selection" => Some(DeductionMode::NoSelection),
            _ => None,
        }
    }
}

/// A trained end-to-end predictor for one scenario.
pub struct ScenarioPredictor<'a> {
    pub scenario: Scenario,
    pub method: Method,
    pub mode: DeductionMode,
    pub models: BTreeMap<String, TrainedModel<'a>>,
    /// Estimated framework overhead (mean end-to-end minus op-sum gap).
    pub t_overhead_ms: f64,
    /// Buckets seen at prediction time with no trained model (counted, and
    /// predicted with the global mean op latency as fallback).
    pub fallback_ms: f64,
}

/// Merge Winograd/Conv2D buckets for the NoSelection ablation.
fn ablate_bucket(bucket: &str, mode: DeductionMode) -> String {
    if mode == DeductionMode::NoSelection
        && matches!(bucket, "Winograd" | "GroupedConv2D" | "NaiveGroupedConv2D")
    {
        "Conv2D".to_string()
    } else {
        bucket.to_string()
    }
}

/// Deduce the predicted units of a graph under a scenario: features + bucket
/// for every op (CPU) or deduced kernel (GPU, fusion + selection per
/// Section 4.1). Pure in (scenario, mode, graph) — the serving engine
/// memoizes it by graph fingerprint.
pub fn deduce_units(sc: &Scenario, mode: DeductionMode, g: &Graph) -> Vec<(String, Vec<f64>)> {
    match &sc.target {
        Target::Cpu { .. } => g
            .nodes
            .iter()
            .map(|n| (cpu_bucket(n), features(g, n)))
            .collect(),
        Target::Gpu { options } => {
            let opts = match mode {
                DeductionMode::Full => *options,
                DeductionMode::NoFusion => CompileOptions { fusion: false, ..*options },
                DeductionMode::NoSelection => *options,
            };
            let kernels = if opts.fusion {
                compile(g, sc.soc.gpu.kind, opts).kernels
            } else {
                let mut ks = fusion::no_fuse(g);
                for k in &mut ks {
                    k.impl_ = crate::tflite::select::select_for_kernel(
                        g,
                        k,
                        sc.soc.gpu.kind,
                        opts,
                    );
                }
                ks
            };
            kernels
                .iter()
                .map(|k| {
                    let b = ablate_bucket(&bucket_of(g, k), mode);
                    let mut f = kernel_features(g, k);
                    if mode == DeductionMode::NoSelection {
                        conform_conv_kernel_row(&mut f);
                    }
                    (b, f)
                })
                .collect()
        }
    }
}

impl<'a> ScenarioPredictor<'a> {
    /// Assemble a predictor from already-trained parts — the path used when
    /// loading a serialized `engine::PredictorBundle`.
    pub fn from_parts(
        scenario: Scenario,
        method: Method,
        mode: DeductionMode,
        models: BTreeMap<String, TrainedModel<'a>>,
        t_overhead_ms: f64,
        fallback_ms: f64,
    ) -> ScenarioPredictor<'a> {
        ScenarioPredictor { scenario, method, mode, models, t_overhead_ms, fallback_ms }
    }

    /// Train per-bucket models from profiles of the training architectures.
    pub fn train_from(
        scenario: &Scenario,
        profiles: &[ModelProfile],
        method: Method,
        mode: DeductionMode,
        seed: u64,
        mlp_ctx: Option<&'a MlpContext>,
    ) -> ScenarioPredictor<'a> {
        let mut data = bucket_datasets(profiles);
        if mode == DeductionMode::NoSelection {
            // Merge all convolution kernels into one Conv2D bucket.
            let mut merged = crate::profiler::BucketData::default();
            for b in ["Conv2D", "Winograd", "GroupedConv2D", "NaiveGroupedConv2D"] {
                if let Some(d) = data.remove(b) {
                    // Drop the group-count feature where present so rows
                    // align (same conform as prediction-time deduction).
                    for (mut x, y) in d.x.into_iter().zip(d.y) {
                        conform_conv_kernel_row(&mut x);
                        merged.x.push(x);
                        merged.y.push(y);
                    }
                }
            }
            if !merged.x.is_empty() {
                data.insert("Conv2D".into(), merged);
            }
        }
        let mut models = BTreeMap::new();
        for (bucket, d) in &data {
            if d.x.is_empty() {
                continue;
            }
            models.insert(bucket.clone(), train(method, &d.x, &d.y, seed, mlp_ctx));
        }
        let gaps: Vec<f64> = profiles.iter().map(|p| p.overhead_ms()).collect();
        let all_lat: Vec<f64> =
            profiles.iter().flat_map(|p| p.ops.iter().map(|o| o.latency_ms)).collect();
        ScenarioPredictor {
            scenario: scenario.clone(),
            method,
            mode,
            models,
            t_overhead_ms: mean(&gaps).max(0.0),
            fallback_ms: mean(&all_lat),
        }
    }

    /// Features + bucket for every predicted unit of a graph under this
    /// scenario (CPU: ops; GPU: deduced kernels).
    pub fn units(&self, g: &Graph) -> Vec<(String, Vec<f64>)> {
        deduce_units(&self.scenario, self.mode, g)
    }

    /// Predict the latency of each unit.
    pub fn predict_units(&self, g: &Graph) -> Vec<(String, f64)> {
        self.units(g)
            .into_iter()
            .map(|(bucket, f)| {
                let ms = match self.models.get(&bucket) {
                    Some(m) => m.predict_raw(&f),
                    None => self.fallback_ms,
                };
                (bucket, ms)
            })
            .collect()
    }

    /// End-to-end prediction: `T_overhead + Σ f*_c(x_c)` (Section 4.2).
    pub fn predict(&self, g: &Graph) -> f64 {
        self.t_overhead_ms + self.predict_units(g).iter().map(|(_, ms)| ms).sum::<f64>()
    }
}

/// End-to-end + per-bucket MAPE of a predictor over test profiles.
pub struct Evaluation {
    pub end_to_end_mape: f64,
    pub per_bucket_mape: BTreeMap<String, f64>,
    pub predictions: Vec<(String, f64, f64)>, // (model, predicted, measured)
}

/// Evaluate a scenario predictor against measured test profiles.
pub fn evaluate(
    pred: &ScenarioPredictor,
    test_graphs: &[Graph],
    test_profiles: &[ModelProfile],
) -> Evaluation {
    assert_eq!(test_graphs.len(), test_profiles.len());
    let mut predictions = Vec::new();
    let mut e2e_pred = Vec::new();
    let mut e2e_meas = Vec::new();
    let mut bucket_pred: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for (g, p) in test_graphs.iter().zip(test_profiles) {
        // One deduction pass per graph: the unit predictions also yield the
        // end-to-end sum (the old predict + predict_units pair deduced the
        // kernels twice).
        let units = pred.predict_units(g);
        let e = pred.t_overhead_ms + units.iter().map(|(_, ms)| ms).sum::<f64>();
        predictions.push((g.name.clone(), e, p.end_to_end_ms));
        e2e_pred.push(e);
        e2e_meas.push(p.end_to_end_ms);
        // Per-unit comparison: deduced units must align with measured ops
        // when the deduction mode matches the device compilation (Full).
        if pred.mode == DeductionMode::Full && units.len() == p.ops.len() {
            for ((b, pm), o) in units.iter().zip(&p.ops) {
                let e = bucket_pred.entry(b.clone()).or_default();
                e.0.push(*pm);
                e.1.push(o.latency_ms);
            }
        }
    }
    let per_bucket_mape = bucket_pred
        .into_iter()
        .map(|(b, (p, a))| (b, mape(&p, &a)))
        .collect();
    Evaluation {
        end_to_end_mape: mape(&e2e_pred, &e2e_meas),
        per_bucket_mape,
        predictions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::profile_set;
    use crate::scenario;

    fn train_graphs(n: usize) -> Vec<Graph> {
        crate::nas::sample_dataset(1234, n).into_iter().map(|a| a.graph).collect()
    }

    #[test]
    fn cpu_predictor_achieves_low_mape_in_distribution() {
        // Default NAS setting (Section 5.1): train and test from the same
        // space; GBDT should land in single-digit MAPE.
        let sc = scenario::one_large_core("Snapdragon855");
        let graphs = train_graphs(60);
        let profiles = profile_set(&sc, &graphs, 7, 5);
        let (tr_g, te_g) = graphs.split_at(45);
        let (tr_p, te_p) = profiles.split_at(45);
        let pred = ScenarioPredictor::train_from(
            &sc,
            tr_p,
            Method::Gbdt,
            DeductionMode::Full,
            1,
            None,
        );
        let ev = evaluate(&pred, te_g, te_p);
        assert!(
            ev.end_to_end_mape < 0.12,
            "GBDT e2e MAPE {:.3} too high",
            ev.end_to_end_mape
        );
        let _ = tr_g;
    }

    #[test]
    fn gpu_predictor_units_match_measured_kernels() {
        let soc = crate::device::soc_by_name("Exynos9820").unwrap();
        let sc = Scenario::gpu(&soc);
        let graphs = train_graphs(12);
        let profiles = profile_set(&sc, &graphs, 3, 3);
        let pred = ScenarioPredictor::train_from(
            &sc,
            &profiles,
            Method::Lasso,
            DeductionMode::Full,
            1,
            None,
        );
        for (g, p) in graphs.iter().zip(&profiles) {
            let units = pred.units(g);
            assert_eq!(units.len(), p.ops.len(), "{}", g.name);
            for (u, o) in units.iter().zip(&p.ops) {
                assert_eq!(u.0, o.bucket, "{}", g.name);
            }
        }
    }

    #[test]
    fn overhead_estimated_positive() {
        let soc = crate::device::soc_by_name("HelioP35").unwrap();
        let sc = Scenario::gpu(&soc);
        let graphs = train_graphs(8);
        let profiles = profile_set(&sc, &graphs, 5, 3);
        let pred = ScenarioPredictor::train_from(
            &sc,
            &profiles,
            Method::Lasso,
            DeductionMode::Full,
            2,
            None,
        );
        // HelioP35 GPU overhead is 7.5ms mean in the simulator.
        assert!(
            (3.0..14.0).contains(&pred.t_overhead_ms),
            "t_overhead={}",
            pred.t_overhead_ms
        );
    }

    #[test]
    fn no_fusion_ablation_overpredicts() {
        // Predicting unfused ops while the device fuses them must
        // overestimate latency (Fig 19 error reduction).
        let soc = crate::device::soc_by_name("Snapdragon855").unwrap();
        let sc = Scenario::gpu(&soc);
        let graphs = train_graphs(15);
        let profiles = profile_set(&sc, &graphs, 9, 3);
        // Train the NoFusion predictor on unfused profiles (fusion disabled
        // during its calibration runs), as the paper's baseline would.
        let sc_nofuse = Scenario {
            target: Target::Gpu {
                options: CompileOptions { fusion: false, ..Default::default() },
            },
            ..sc.clone()
        };
        let profiles_nofuse = profile_set(&sc_nofuse, &graphs, 9, 3);
        let full = ScenarioPredictor::train_from(
            &sc, &profiles, Method::Gbdt, DeductionMode::Full, 3, None,
        );
        let nofuse = ScenarioPredictor::train_from(
            &sc_nofuse, &profiles_nofuse, Method::Gbdt, DeductionMode::NoFusion, 3, None,
        );
        let (te_g, te_p) = (&graphs[10..], &profiles[10..]);
        let ev_full = evaluate(&full, te_g, te_p);
        let ev_nofuse = evaluate(&nofuse, te_g, te_p);
        assert!(
            ev_nofuse.end_to_end_mape > ev_full.end_to_end_mape,
            "full={:.3} nofusion={:.3}",
            ev_full.end_to_end_mape,
            ev_nofuse.end_to_end_mape
        );
    }
}
