//! The end-to-end latency prediction framework (Section 4).
//!
//! Given a model file and a target scenario, and *without* touching the
//! device: (1) extract the computational graph; (2) for GPUs, deduce the
//! kernels TFLite would execute (fusion + selection, Section 4.1); (3)
//! predict each op/kernel's latency with the per-bucket ML model trained
//! from one-time profiling data (Section 4.2); (4) report
//! `T_overhead + Σ_c f*_c(x_c)`, where `T_overhead` is the mean measured
//! gap between end-to-end latency and the op sum on the training set.
//!
//! [`ScenarioPredictor`] is the training-side view; for the train-once /
//! serialize / load / batch-predict serving path built on top of it, see
//! `crate::engine`. Both predict over the lowered-plan IR (`crate::plan`):
//! lower a graph once with [`plan::lower`], then evaluate per-bucket models
//! against the dense plan ([`ScenarioPredictor::predict_plan`]);
//! [`deduce_units`] is the string-keyed reference path kept for parity
//! testing and compatibility.

use crate::features::{
    bucket_name_of, conform_conv_kernel_row, cpu_bucket, features, kernel_features,
};
use crate::graph::Graph;
use crate::plan::{self, BucketId, LoweredGraph};
use crate::predict::lut::{LutPack, LutSpec};
use crate::predict::{mlp::MlpContext, soa, train, Method, TrainedModel};
use crate::profiler::{bucket_datasets, ModelProfile};
use crate::scenario::Scenario;
use crate::tflite::{compile, CompileOptions};
use crate::util::{mape, mean};
use crate::device::Target;
use std::collections::BTreeMap;

/// How the predictor handles ML-framework optimizations — the ablations of
/// Section 5.4 (Figs 19, 20).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeductionMode {
    /// Full kernel deduction: fusion + kernel selection (the paper's method).
    Full,
    /// Ignore kernel fusion: predict each graph op as its own kernel.
    NoFusion,
    /// Ignore kernel selection: all convolutions use the Conv2D bucket.
    NoSelection,
}

impl DeductionMode {
    /// Stable name used by the CLI and bundle files.
    pub fn name(&self) -> &'static str {
        match self {
            DeductionMode::Full => "full",
            DeductionMode::NoFusion => "nofusion",
            DeductionMode::NoSelection => "noselection",
        }
    }

    pub fn parse(s: &str) -> Option<DeductionMode> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Some(DeductionMode::Full),
            "nofusion" | "no_fusion" => Some(DeductionMode::NoFusion),
            "noselection" | "no_selection" => Some(DeductionMode::NoSelection),
            _ => None,
        }
    }
}

/// A trained end-to-end predictor for one scenario.
///
/// Per-bucket models live in a dense table indexed by
/// [`plan::BucketId`] — the predict hot path ([`predict_plan`]) does no
/// string hashing and no bucket-name clones. The string-keyed accessors
/// ([`model_named`], [`models`]) resolve through the interner.
///
/// [`predict_plan`]: Self::predict_plan
/// [`model_named`]: Self::model_named
/// [`models`]: Self::models
pub struct ScenarioPredictor<'a> {
    pub scenario: Scenario,
    pub method: Method,
    pub mode: DeductionMode,
    /// Dense per-bucket model table, indexed by `BucketId`
    /// (`len == plan::interner().len()`).
    models: Vec<Option<TrainedModel<'a>>>,
    /// Estimated framework overhead (mean end-to-end minus op-sum gap).
    pub t_overhead_ms: f64,
    /// Buckets seen at prediction time with no trained model (counted, and
    /// predicted with the global mean op latency as fallback).
    pub fallback_ms: f64,
    /// Per-bucket SoA kernels compiled once from the owned native models
    /// (parallel to `models`; `None` for missing or engine-external
    /// models, which stay on the scalar path).
    kernels: Vec<Option<soa::BucketKernel>>,
}

/// Compile the vectorized kernel table for a dense model table.
fn compile_kernels(models: &[Option<TrainedModel<'_>>]) -> Vec<Option<soa::BucketKernel>> {
    models
        .iter()
        .map(|m| m.as_ref().and_then(TrainedModel::as_owned).map(soa::BucketKernel::compile))
        .collect()
}

/// Intern a by-name model map into the dense `BucketId`-indexed table.
fn dense_models<'a>(named: BTreeMap<String, TrainedModel<'a>>) -> Vec<Option<TrainedModel<'a>>> {
    let it = plan::interner();
    let mut models: Vec<Option<TrainedModel<'a>>> = (0..it.len()).map(|_| None).collect();
    for (bucket, m) in named {
        let id = it
            .resolve(&bucket)
            .unwrap_or_else(|| panic!("bucket '{bucket}' not in the interner table"));
        models[id.index()] = Some(m);
    }
    models
}

/// Deduce the predicted units of a graph under a scenario: features + bucket
/// for every op (CPU) or deduced kernel (GPU, fusion + selection per
/// Section 4.1). Pure in (scenario, mode, graph).
///
/// This is the string-keyed **reference** implementation; every hot path
/// now goes through [`plan::lower`], which packs the same units into the
/// dense [`LoweredGraph`] IR. The unit *derivation* (compile, features,
/// ablate, conform) is shared — the IR differs only in packing — and
/// `tests/properties.rs` asserts the two agree bit-for-bit across all 72
/// scenarios and every deduction mode.
pub fn deduce_units(sc: &Scenario, mode: DeductionMode, g: &Graph) -> Vec<(String, Vec<f64>)> {
    // Workload columns mirror `plan::lower` exactly: appended after any
    // conform step, absent for isolated scenarios.
    let wl_cols = crate::workload::feature_cols(sc);
    match &sc.target {
        Target::Cpu { .. } => g
            .nodes
            .iter()
            .map(|n| {
                let mut f = features(g, n);
                if let Some(cols) = wl_cols {
                    f.extend_from_slice(&cols);
                }
                (cpu_bucket(n), f)
            })
            .collect(),
        Target::Gpu { options } => {
            let opts = match mode {
                DeductionMode::Full | DeductionMode::NoSelection => *options,
                DeductionMode::NoFusion => CompileOptions { fusion: false, ..*options },
            };
            // `compile` runs no_fuse + per-kernel selection when fusion is
            // off, so one call covers the NoFusion ablation too.
            compile(g, sc.soc.gpu.kind, opts)
                .kernels
                .iter()
                .map(|k| {
                    let b = plan::ablate(bucket_name_of(g, k), mode).to_string();
                    let mut f = kernel_features(g, k);
                    if mode == DeductionMode::NoSelection {
                        conform_conv_kernel_row(&mut f);
                    }
                    if let Some(cols) = wl_cols {
                        f.extend_from_slice(&cols);
                    }
                    (b, f)
                })
                .collect()
        }
    }
}

impl<'a> ScenarioPredictor<'a> {
    /// Assemble a predictor from already-trained parts — the path used when
    /// loading a serialized `engine::PredictorBundle`.
    ///
    /// Panics if a model is keyed by a bucket name the interner does not
    /// know; the bundle load paths (`from_json`, `to_predictor`,
    /// `EngineBuilder::build`) validate names first and surface an error
    /// instead.
    pub fn from_parts(
        scenario: Scenario,
        method: Method,
        mode: DeductionMode,
        models: BTreeMap<String, TrainedModel<'a>>,
        t_overhead_ms: f64,
        fallback_ms: f64,
    ) -> ScenarioPredictor<'a> {
        let models = dense_models(models);
        let kernels = compile_kernels(&models);
        ScenarioPredictor { scenario, method, mode, models, t_overhead_ms, fallback_ms, kernels }
    }

    /// Train per-bucket models from profiles of the training architectures.
    pub fn train_from(
        scenario: &Scenario,
        profiles: &[ModelProfile],
        method: Method,
        mode: DeductionMode,
        seed: u64,
        mlp_ctx: Option<&'a MlpContext>,
    ) -> ScenarioPredictor<'a> {
        let mut data = bucket_datasets(profiles);
        if mode == DeductionMode::NoSelection {
            // Merge all convolution kernels into one Conv2D bucket.
            let mut merged = crate::profiler::BucketData::default();
            for b in ["Conv2D", "Winograd", "GroupedConv2D", "NaiveGroupedConv2D"] {
                if let Some(d) = data.remove(b) {
                    // Drop the group-count feature where present so rows
                    // align (same conform as prediction-time deduction).
                    for (mut x, y) in d.x.into_iter().zip(d.y) {
                        conform_conv_kernel_row(&mut x);
                        merged.x.push(x);
                        merged.y.push(y);
                    }
                }
            }
            if !merged.x.is_empty() {
                data.insert("Conv2D".into(), merged);
            }
        }
        let mut models = BTreeMap::new();
        for (bucket, d) in &data {
            if d.x.is_empty() {
                continue;
            }
            models.insert(bucket.clone(), train(method, &d.x, &d.y, seed, mlp_ctx));
        }
        let gaps: Vec<f64> = profiles.iter().map(|p| p.overhead_ms()).collect();
        let all_lat: Vec<f64> =
            profiles.iter().flat_map(|p| p.ops.iter().map(|o| o.latency_ms)).collect();
        let models = dense_models(models);
        let kernels = compile_kernels(&models);
        ScenarioPredictor {
            scenario: scenario.clone(),
            method,
            mode,
            models,
            t_overhead_ms: mean(&gaps).max(0.0),
            fallback_ms: mean(&all_lat),
            kernels,
        }
    }

    /// The trained model for a bucket id, if any.
    pub fn model(&self, b: BucketId) -> Option<&TrainedModel<'a>> {
        self.models[b.index()].as_ref()
    }

    /// String-keyed model lookup (resolved through the interner) — for
    /// inspection paths like the Lasso feature-importance report, not for
    /// the predict loop.
    pub fn model_named(&self, bucket: &str) -> Option<&TrainedModel<'a>> {
        plan::interner().resolve(bucket).and_then(|b| self.model(b))
    }

    /// Iterate the trained per-bucket models in bucket-id order.
    pub fn models(&self) -> impl Iterator<Item = (&'static str, &TrainedModel<'a>)> + '_ {
        let it = plan::interner();
        self.models
            .iter()
            .enumerate()
            .filter_map(move |(i, m)| m.as_ref().map(|m| (it.names()[i], m)))
    }

    /// Number of buckets with a trained model.
    pub fn model_count(&self) -> usize {
        self.models.iter().filter(|m| m.is_some()).count()
    }

    /// Lower a graph under this predictor's (scenario, mode) — the
    /// featurize-once half of the serve path. The returned plan can be
    /// evaluated by any predictor sharing the same (scenario, mode).
    pub fn lower(&self, g: &Graph) -> LoweredGraph {
        plan::lower(&self.scenario, self.mode, g)
    }

    /// Per-unit latency predictions over an already-lowered plan, in
    /// execution order. **The matrix-first primitive** every other predict
    /// entry point shims over: units are grouped by bucket and evaluated
    /// through the vectorized SoA kernels compiled at construction
    /// (`predict::soa`), with engine-external (MLP) models and model-less
    /// buckets on the scalar path. Bit-identical to
    /// [`predict_plan_rows_scalar`](Self::predict_plan_rows_scalar).
    pub fn predict_plan_rows(&self, p: &LoweredGraph) -> Vec<f64> {
        self.predict_plan_rows_lut(p, None)
    }

    /// [`predict_plan_rows`](Self::predict_plan_rows) with an optional
    /// compiled LUT tier in front of the SoA kernels: in-grid rows are
    /// answered from the table (see [`compile_lut`](Self::compile_lut)),
    /// everything else — out-of-grid rows, uncovered buckets — takes the
    /// vectorized/scalar path bit-identically to `lut: None`.
    pub fn predict_plan_rows_lut(&self, p: &LoweredGraph, lut: Option<&LutPack>) -> Vec<f64> {
        let (rows, _) =
            soa::eval_plan_grouped(p, &self.kernels, self.fallback_ms, lut, |bi, row, scratch| {
                self.models[bi].as_ref().map(|m| m.predict_raw_with(row, scratch))
            });
        rows
    }

    /// Compile the trained per-bucket models into a direct-lookup tier
    /// ([`predict::lut`](crate::predict::lut)) calibrated on the feature
    /// rows of `plans` — the closed workload whose rows should become
    /// index computations. Tables are verified against the full model at
    /// build time (`spec.max_rel_err`); buckets that fail verification
    /// or would need oversized grids simply stay on the SoA path.
    pub fn compile_lut(&self, spec: &LutSpec, plans: &[&LoweredGraph]) -> LutPack {
        let dims: Vec<Option<usize>> = (0..self.models.len())
            .map(|bi| self.models[bi].as_ref().map(|m| m.feature_dim()))
            .collect();
        let mut scratch: Vec<f64> = Vec::new();
        LutPack::compile(spec, &dims, plans, |bi, row| {
            self.models[bi].as_ref().map(|m| m.predict_raw_with(row, &mut scratch))
        })
    }

    /// Scalar reference implementation of
    /// [`predict_plan_rows`](Self::predict_plan_rows): one unit at a time
    /// through the per-row model path. Kept as the ground truth the
    /// vectorized kernels are proven bit-identical against (see
    /// `tests/vector_kernels.rs` and the bench fleet stage).
    pub fn predict_plan_rows_scalar(&self, p: &LoweredGraph) -> Vec<f64> {
        let mut scratch = Vec::new();
        p.iter()
            .map(|(b, row)| match &self.models[b.index()] {
                Some(m) => m.predict_raw_with(row, &mut scratch),
                None => self.fallback_ms,
            })
            .collect()
    }

    /// End-to-end prediction over an already-lowered plan:
    /// `T_overhead + Σ f*_c(x_c)` (Section 4.2). Sums the
    /// [`predict_plan_rows`](Self::predict_plan_rows) vector in execution
    /// order — the same addition sequence as the old scalar loop.
    pub fn predict_plan(&self, p: &LoweredGraph) -> f64 {
        self.t_overhead_ms + self.predict_plan_rows(p).iter().sum::<f64>()
    }

    /// Features + bucket for every predicted unit of a graph under this
    /// scenario (CPU: ops; GPU: deduced kernels). String-keyed
    /// compatibility shim over [`lower`](Self::lower).
    pub fn units(&self, g: &Graph) -> Vec<(String, Vec<f64>)> {
        self.lower(g).to_units()
    }

    /// Predict the latency of each unit. **Shim over
    /// [`predict_plan_rows`](Self::predict_plan_rows)**: lowers once, runs
    /// the matrix-first primitive, and resolves bucket names through the
    /// interner for the string-keyed return.
    pub fn predict_units(&self, g: &Graph) -> Vec<(String, f64)> {
        let it = plan::interner();
        let p = self.lower(g);
        let rows = self.predict_plan_rows(&p);
        p.buckets()
            .iter()
            .zip(rows)
            .map(|(&b, ms)| (it.name(b).to_string(), ms))
            .collect()
    }

    /// End-to-end prediction: `T_overhead + Σ f*_c(x_c)` (Section 4.2).
    /// **Shim over [`predict_plan_rows`](Self::predict_plan_rows)** via
    /// [`predict_plan`](Self::predict_plan): lower once, evaluate the
    /// matrix-first primitive, add `t_overhead_ms`.
    pub fn predict(&self, g: &Graph) -> f64 {
        self.predict_plan(&self.lower(g))
    }
}

/// End-to-end + per-bucket MAPE of a predictor over test profiles.
pub struct Evaluation {
    pub end_to_end_mape: f64,
    pub per_bucket_mape: BTreeMap<String, f64>,
    pub predictions: Vec<(String, f64, f64)>, // (model, predicted, measured)
}

/// Evaluate a scenario predictor against measured test profiles. Lowers
/// each test graph once; callers that already hold plans (the report
/// sweeps share one plan set across Lasso/RF/GBDT) use
/// [`evaluate_lowered`] directly.
pub fn evaluate(
    pred: &ScenarioPredictor,
    test_graphs: &[Graph],
    test_profiles: &[ModelProfile],
) -> Evaluation {
    let plans: Vec<LoweredGraph> = test_graphs.iter().map(|g| pred.lower(g)).collect();
    evaluate_lowered(pred, test_graphs, &plans, test_profiles)
}

/// Evaluate over already-lowered plans (`plans[i]` is `test_graphs[i]`
/// lowered under the predictor's (scenario, mode)). The prediction loop is
/// the id-indexed plan path — no per-unit bucket strings.
pub fn evaluate_lowered(
    pred: &ScenarioPredictor,
    test_graphs: &[Graph],
    plans: &[LoweredGraph],
    test_profiles: &[ModelProfile],
) -> Evaluation {
    assert_eq!(test_graphs.len(), test_profiles.len());
    assert_eq!(test_graphs.len(), plans.len());
    let it = plan::interner();
    let mut predictions = Vec::new();
    let mut e2e_pred = Vec::new();
    let mut e2e_meas = Vec::new();
    let mut bucket_pred: BTreeMap<&'static str, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for ((g, pl), p) in test_graphs.iter().zip(plans).zip(test_profiles) {
        // One lowering per graph yields both the per-unit rows and the
        // end-to-end sum.
        let rows = pred.predict_plan_rows(pl);
        let e = pred.t_overhead_ms + rows.iter().sum::<f64>();
        predictions.push((g.name.clone(), e, p.end_to_end_ms));
        e2e_pred.push(e);
        e2e_meas.push(p.end_to_end_ms);
        // Per-unit comparison: deduced units must align with measured ops
        // when the deduction mode matches the device compilation (Full).
        if pred.mode == DeductionMode::Full && pl.len() == p.ops.len() {
            for (i, (pm, o)) in rows.iter().zip(&p.ops).enumerate() {
                let e = bucket_pred.entry(it.name(pl.bucket(i))).or_default();
                e.0.push(*pm);
                e.1.push(o.latency_ms);
            }
        }
    }
    let per_bucket_mape = bucket_pred
        .into_iter()
        .map(|(b, (p, a))| (b.to_string(), mape(&p, &a)))
        .collect();
    Evaluation {
        end_to_end_mape: mape(&e2e_pred, &e2e_meas),
        per_bucket_mape,
        predictions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::profile_set;
    use crate::scenario;

    fn train_graphs(n: usize) -> Vec<Graph> {
        crate::nas::sample_dataset(1234, n).into_iter().map(|a| a.graph).collect()
    }

    #[test]
    fn cpu_predictor_achieves_low_mape_in_distribution() {
        // Default NAS setting (Section 5.1): train and test from the same
        // space; GBDT should land in single-digit MAPE.
        let sc = scenario::one_large_core("Snapdragon855").unwrap();
        let graphs = train_graphs(60);
        let profiles = profile_set(&sc, &graphs, 7, 5);
        let (tr_g, te_g) = graphs.split_at(45);
        let (tr_p, te_p) = profiles.split_at(45);
        let pred = ScenarioPredictor::train_from(
            &sc,
            tr_p,
            Method::Gbdt,
            DeductionMode::Full,
            1,
            None,
        );
        let ev = evaluate(&pred, te_g, te_p);
        assert!(
            ev.end_to_end_mape < 0.12,
            "GBDT e2e MAPE {:.3} too high",
            ev.end_to_end_mape
        );
        let _ = tr_g;
    }

    #[test]
    fn gpu_predictor_units_match_measured_kernels() {
        let soc = crate::device::soc_by_name("Exynos9820").unwrap();
        let sc = Scenario::gpu(&soc);
        let graphs = train_graphs(12);
        let profiles = profile_set(&sc, &graphs, 3, 3);
        let pred = ScenarioPredictor::train_from(
            &sc,
            &profiles,
            Method::Lasso,
            DeductionMode::Full,
            1,
            None,
        );
        for (g, p) in graphs.iter().zip(&profiles) {
            let units = pred.units(g);
            assert_eq!(units.len(), p.ops.len(), "{}", g.name);
            for (u, o) in units.iter().zip(&p.ops) {
                assert_eq!(u.0, o.bucket, "{}", g.name);
            }
        }
    }

    #[test]
    fn overhead_estimated_positive() {
        let soc = crate::device::soc_by_name("HelioP35").unwrap();
        let sc = Scenario::gpu(&soc);
        let graphs = train_graphs(8);
        let profiles = profile_set(&sc, &graphs, 5, 3);
        let pred = ScenarioPredictor::train_from(
            &sc,
            &profiles,
            Method::Lasso,
            DeductionMode::Full,
            2,
            None,
        );
        // HelioP35 GPU overhead is 7.5ms mean in the simulator.
        assert!(
            (3.0..14.0).contains(&pred.t_overhead_ms),
            "t_overhead={}",
            pred.t_overhead_ms
        );
    }

    #[test]
    fn no_fusion_ablation_overpredicts() {
        // Predicting unfused ops while the device fuses them must
        // overestimate latency (Fig 19 error reduction).
        let soc = crate::device::soc_by_name("Snapdragon855").unwrap();
        let sc = Scenario::gpu(&soc);
        let graphs = train_graphs(15);
        let profiles = profile_set(&sc, &graphs, 9, 3);
        // Train the NoFusion predictor on unfused profiles (fusion disabled
        // during its calibration runs), as the paper's baseline would.
        let sc_nofuse = Scenario {
            target: Target::Gpu {
                options: CompileOptions { fusion: false, ..Default::default() },
            },
            ..sc.clone()
        };
        let profiles_nofuse = profile_set(&sc_nofuse, &graphs, 9, 3);
        let full = ScenarioPredictor::train_from(
            &sc, &profiles, Method::Gbdt, DeductionMode::Full, 3, None,
        );
        let nofuse = ScenarioPredictor::train_from(
            &sc_nofuse, &profiles_nofuse, Method::Gbdt, DeductionMode::NoFusion, 3, None,
        );
        let (te_g, te_p) = (&graphs[10..], &profiles[10..]);
        let ev_full = evaluate(&full, te_g, te_p);
        let ev_nofuse = evaluate(&nofuse, te_g, te_p);
        assert!(
            ev_nofuse.end_to_end_mape > ev_full.end_to_end_mape,
            "full={:.3} nofusion={:.3}",
            ev_full.end_to_end_mape,
            ev_nofuse.end_to_end_mape
        );
    }
}
