//! # edgelat — Inference Latency Prediction at the Edge
//!
//! A full reproduction of *"Inference Latency Prediction at the Edge"*
//! (Li, Paolieri, Golubchik, 2022): operation-wise latency prediction for
//! neural-network inference on mobile SoCs, evaluated against a simulated
//! big.LITTLE CPU + mobile-GPU substrate (see DESIGN.md for the hardware
//! substitution argument).
//!
//! Architecture (three layers):
//! - **L3 (this crate)**: computational-graph IR, real-world model zoo, NAS
//!   sampler, TFLite compile simulation (kernel fusion/selection), device
//!   simulator, profiler, feature extraction, Lasso/RF/GBDT predictors, and
//!   the end-to-end prediction framework + evaluation harness.
//! - **L2 (python/compile/model.py, build-time only)**: the MLP latency
//!   predictor's forward/backward in JAX, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/, build-time only)**: the MLP's fused
//!   dense layer as a Pallas kernel (interpret mode), verified vs a jnp
//!   oracle.
//!
//! The rust binary executes the AOT-compiled MLP via the PJRT C API
//! (`runtime`); Python never runs on the request path.

pub mod device;
pub mod graph;
pub mod features;
pub mod framework;
pub mod nas;
pub mod predict;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod tflite;
pub mod util;
pub mod zoo;
