//! # edgelat — Inference Latency Prediction at the Edge
//!
//! A full reproduction of *"Inference Latency Prediction at the Edge"*
//! (Li, Paolieri, Golubchik, 2022): operation-wise latency prediction for
//! neural-network inference on mobile SoCs, evaluated against a simulated
//! big.LITTLE CPU + mobile-GPU substrate (see DESIGN.md for the hardware
//! substitution argument).
//!
//! Architecture (three layers, with the top layer split into an offline
//! training path and an online serving path):
//! - **L3 offline (this crate)**: computational-graph IR (`graph`),
//!   real-world model zoo (`zoo`), NAS sampler (`nas`), TFLite compile
//!   simulation — kernel fusion/selection (`tflite`) — device simulator
//!   (`device`), profiler (`profiler`), feature extraction (`features`),
//!   Lasso/RF/GBDT/MLP predictors (`predict`), and the end-to-end training
//!   + evaluation framework (`framework`, `report`).
//! - **Open device universe (`device::spec` + `scenario::Registry`)**: a
//!   SoC is *data*, not code — a versioned JSON device spec (clusters,
//!   frequencies, bandwidth/cost-model parameters, GPU block, studied core
//!   combos). The paper's four Table 1 devices ship as committed spec
//!   files (`device/specs/*.json`, parsed once at startup and reproducing
//!   the 72 scenarios bit-identically); any new device registers at
//!   runtime via `Registry::load_spec_json` / `--device-spec FILE.json`.
//!   The `Registry` is the single source of scenario truth — fallible,
//!   typed lookups (`ScenarioError`), `Arc`-shared scenarios, and it
//!   threads through the profiler, the report context (`ReportCtx`), the
//!   search CLI, and the bench suite. Predictor bundles (v3) embed the
//!   full scenario descriptor, so a bundle trained on a never-seen device
//!   loads and serves anywhere without its spec file. A seed-deterministic
//!   spec sampler (`device::sample_specs`) generates hundreds of
//!   schema-valid synthetic SoCs on demand — the fleet-scale universe the
//!   bench suite's fleet stage registers and sweeps.
//! - **Lowered-plan IR (`plan`)**: the shared representation between
//!   deduction and prediction. A `BucketInterner` fixes the closed bucket
//!   universe into dense `BucketId`s; `plan::lower(scenario, mode, graph)`
//!   deduces the predicted units once and packs them into a `LoweredGraph`
//!   (execution-ordered `BucketId`s + one flat `f64` feature arena with
//!   row offsets). Predictors evaluate plans with `BucketId`-indexed model
//!   tables — no bucket strings or `HashMap` lookups on the predict hot
//!   path; plans are cached by the engine and shared across model
//!   families by the report sweeps. Prediction itself is matrix-first:
//!   `Regressor::predict` takes a borrowed `predict::FeatureMatrix` view,
//!   and the native models evaluate whole plans through flat
//!   structure-of-arrays kernels (`predict::soa` — level-synchronous
//!   breadth-first tree walks, blocked Lasso GEMV) compiled once per
//!   trained model and proven bit-identical to the scalar per-row
//!   reference (`tests/vector_kernels.rs`). Bundles serialize the intern table;
//!   models re-intern by name on load, and a bundle whose symbols no
//!   longer resolve is rejected.
//! - **L3 serving (`engine`)**: the train-once / serialize / load /
//!   batch-predict layer. A trained predictor becomes a versioned
//!   `PredictorBundle` file; a `Send + Sync` `LatencyEngine` loads one or
//!   more bundles, memoizes the lowered plan per graph fingerprint, and
//!   serves `PredictRequest`s — single or batched across threads — at NAS
//!   search rate without retraining. Bundles persist in two interchangeable
//!   formats: the versioned JSON document (interchange + golden fixtures)
//!   and a compact little-endian binary (`engine::binfmt`, magic
//!   `EDGELATB`) whose sections decode straight into the flattened SoA
//!   layouts — `bundle convert` round-trips the two bit-exactly, and every
//!   loader (`EngineBuilder::bundle_file`, `serve --bundles`, hot reload)
//!   sniffs the magic and accepts either.
//! - **Compiled LUT tier (`predict::lut`)**: an optional pre-evaluation
//!   tier above the SoA kernels — per-bucket models are baked over
//!   quantized per-feature grids into direct-lookup tables with
//!   multilinear interpolation, each table verified against the model on
//!   every calibration row and dropped unless it meets the `LutSpec`
//!   relative-error bound. Rows off the grid (or in buckets without a
//!   table) fall back bit-identically to the SoA scan, and atomic
//!   `LutCounts` account for every row (lookups / interpolations /
//!   fallbacks — surfaced in serve `stats`). Opt-in via
//!   `EngineBuilder::lut` / `serve --lut`; the bench suite gates the tier
//!   against the SoA scan and the binary decode against the JSON parse.
//! - **Search (`search`)**: the latency-constrained evolutionary NAS
//!   search that drives the serving stack at scale — genomes over the
//!   Section 4.3.2 block space realized via `nas::SynthArch::rebuild`
//!   (divisibility repaired in context), whole generations scored with one
//!   `predict_batch` per scenario (elite survivors hit the fingerprint-
//!   keyed plan cache), per-scenario Pareto fronts (predicted latency vs.
//!   accuracy proxy) and a cross-device Spearman summary. Deterministic in
//!   the seed and thread-count-invariant; `edgelat search` is the CLI.
//! - **Concurrency substrate (`exec_pool`)**: the shared worker-pool
//!   subsystem behind every hot fan-out — a scoped pool with a chunked
//!   atomic work queue, ordered result collection, and per-item error
//!   slots, plus an N-way sharded memo cache. `engine::predict_batch`,
//!   `profiler::profile_set`, and the multi-scenario figure sweeps
//!   (`report::sweep`) all run on it; `bench` (the `edgelat bench`
//!   subcommand) measures those paths and emits the machine-readable
//!   `BENCH_pipeline.json` that CI gates on.
//! - **Serve daemon (`serve`)**: the persistent online half of the
//!   serving story — `edgelat serve` keeps a `BundleFleet` (a directory of
//!   bundles as one hot-reloadable engine) resident behind a
//!   line-oriented JSON-over-TCP protocol, micro-batches concurrent
//!   predict requests into `predict_batch` so the plan cache amortizes
//!   across clients, and exposes `stats`/`reload`/`drain` control verbs
//!   (typed error replies, graceful drain, streaming latency histograms
//!   from `util::timing::LogHistogram`). `edgelat serve-bench` is the
//!   open-loop load generator; the bench suite's serve stage gates its
//!   throughput and tail latency in CI.
//! - **Cross-device transfer (`transfer`)**: few-shot device onboarding —
//!   a trained source bundle plus K profiled (graph, latency) pairs from a
//!   new target SoC become a `TransferBundle`: per-bucket residual
//!   recalibration of the source's native models (rows routed through the
//!   same lowered-plan featurizer the profiler records) under a monotone
//!   piecewise-linear latency map fit by pool-adjacent-violators isotonic
//!   regression — deterministic, no RNG, and never ranking worse than the
//!   proxy baseline it wraps. Transfer bundles serialize through both the
//!   JSON and `EDGELATB`-embedding binary paths (magic `EDGELATT`), load
//!   through every bundle loader (engine builder, serve fleet, hot
//!   reload), and `edgelat transfer eval` emits the byte-reproducible
//!   accuracy-vs-budget curve the bench gate checks.
//! - **Workload axes (`workload`)**: contention- and batch-aware
//!   scenarios. A versioned `WorkloadSpec` (batch size, per-cluster
//!   co-runner load, GPU quota share) is data like a device spec:
//!   committed presets plus `--workload-spec FILE.json` register into the
//!   `Registry` as a cross-product of workload-qualified scenarios
//!   (`BASE@WORKLOAD`), the cost model applies deterministic contention /
//!   batch-amortization multipliers (`device::cost`), lowered-plan rows
//!   gain guarded batch/load/share feature columns, bundles (v4 JSON,
//!   binfmt v2) embed the descriptor, and `edgelat workload eval` emits
//!   the per-scenario RMSPE artifact showing predictors stay accurate
//!   across the enlarged universe. Isolated scenarios (`workload: None`)
//!   stay bit-identical to the paper's 72.
//! - **L2 (python/compile/model.py, build-time only)**: the MLP latency
//!   predictor's forward/backward in JAX, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/, build-time only)**: the MLP's fused
//!   dense layer as a Pallas kernel (interpret mode), verified vs a jnp
//!   oracle.
//!
//! The rust binary executes the AOT-compiled MLP via the PJRT C API
//! (`runtime`); Python never runs on the request path. The MLP stays
//! engine-external (PJRT handles are neither serializable nor `Send`);
//! the serving engine covers the three native methods.

pub mod bench;
pub mod cli;
pub mod device;
pub mod engine;
pub mod exec_pool;
pub mod graph;
pub mod features;
pub mod framework;
pub mod nas;
pub mod plan;
pub mod predict;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod search;
pub mod serve;
pub mod tflite;
pub mod transfer;
pub mod util;
pub mod workload;
pub mod zoo;
