//! Hand-rolled CLI flag parsing (the offline crate set has no clap),
//! extracted from `main.rs` so every parser is a plain testable function.
//!
//! Parsers return `Result<_, String>` instead of exiting; the binary maps
//! errors to `exit(2)` in one place. A flag that is *present* but
//! malformed — missing its value, non-numeric, out of range — is always
//! an error, never a silent fall-back to the default (the old `main.rs`
//! helpers silently defaulted on `--seed` with no value following it).

use crate::framework::DeductionMode;
use crate::predict::Method;
use crate::scenario::{Registry, Scenario};
use std::sync::Arc;

/// Shared defaults: every subcommand that trains reads the same seed /
/// training-set-size / repetition defaults, so `predict`, `evaluate` and
/// `search` cannot drift apart.
pub const DEFAULT_SEED: u64 = 2022;
pub const DEFAULT_TRAIN: usize = 120;
pub const DEFAULT_RUNS: usize = 5;

/// The value following `name`, or `None` when the flag is absent.
/// A present flag with no following value is an error.
pub fn flag(rest: &[String], name: &str) -> Result<Option<String>, String> {
    match rest.iter().position(|a| a == name) {
        None => Ok(None),
        Some(i) => match rest.get(i + 1) {
            Some(v) => Ok(Some(v.clone())),
            None => Err(format!("flag {name} needs a value")),
        },
    }
}

/// Every value of a repeatable flag, in order. Each occurrence must carry
/// a value.
pub fn flag_all(rest: &[String], name: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < rest.len() {
        if rest[i] == name {
            match rest.get(i + 1) {
                Some(v) => {
                    out.push(v.clone());
                    i += 2;
                }
                None => return Err(format!("flag {name} needs a value")),
            }
        } else {
            i += 1;
        }
    }
    Ok(out)
}

/// Presence of a boolean flag.
pub fn has(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

/// Parse a `u64`-valued flag with a default.
pub fn u64_flag(rest: &[String], name: &str, default: u64) -> Result<u64, String> {
    match flag(rest, name)? {
        None => Ok(default),
        Some(s) => s.parse().map_err(|_| format!("{name} expects an unsigned integer, got '{s}'")),
    }
}

/// Parse a `usize`-valued flag with a default.
pub fn usize_flag(rest: &[String], name: &str, default: usize) -> Result<usize, String> {
    match flag(rest, name)? {
        None => Ok(default),
        Some(s) => s.parse().map_err(|_| format!("{name} expects an unsigned integer, got '{s}'")),
    }
}

/// Parse an optional `f64`-valued flag (no default; absent is `None`).
/// The value must be finite and positive — every current use is a
/// latency budget in milliseconds.
pub fn positive_f64_flag(rest: &[String], name: &str) -> Result<Option<f64>, String> {
    match flag(rest, name)? {
        None => Ok(None),
        Some(s) => {
            let v: f64 =
                s.parse().map_err(|_| format!("{name} expects a number, got '{s}'"))?;
            if !v.is_finite() || v <= 0.0 {
                return Err(format!("{name} must be a positive number, got '{s}'"));
            }
            Ok(Some(v))
        }
    }
}

pub fn seed_flag(rest: &[String]) -> Result<u64, String> {
    u64_flag(rest, "--seed", DEFAULT_SEED)
}

pub fn train_flag(rest: &[String]) -> Result<usize, String> {
    let n = usize_flag(rest, "--train", DEFAULT_TRAIN)?;
    if n == 0 {
        return Err("--train needs at least one training architecture".into());
    }
    Ok(n)
}

pub fn runs_flag(rest: &[String]) -> Result<usize, String> {
    let n = usize_flag(rest, "--runs", DEFAULT_RUNS)?;
    if n == 0 {
        return Err("--runs needs at least one profiling repetition".into());
    }
    Ok(n)
}

/// Worker-thread count: absent means "pool default" (`None`); `--threads 0`
/// is accepted and clamps to 1, matching `ExecPool::new` — a pool always
/// has at least one worker, it never means "no execution".
pub fn threads_flag(rest: &[String]) -> Result<Option<usize>, String> {
    match flag(rest, "--threads")? {
        None => Ok(None),
        Some(s) => {
            let n: usize = s
                .parse()
                .map_err(|_| format!("--threads expects an unsigned integer, got '{s}'"))?;
            Ok(Some(n.max(1)))
        }
    }
}

/// A socket address flag (`--addr IP:PORT`), with a default for when the
/// flag is absent. `:0` ports are valid — the serve daemon uses port 0 to
/// bind ephemerally and reports the real port on stdout.
pub fn addr_flag(rest: &[String], default: &str) -> Result<std::net::SocketAddr, String> {
    let s = flag(rest, "--addr")?.unwrap_or_else(|| default.to_string());
    s.parse()
        .map_err(|_| format!("--addr expects IP:PORT (e.g. 127.0.0.1:7878), got '{s}'"))
}

/// `--method`, when present; `None` when the flag is absent (callers that
/// must distinguish "defaulted" from "explicitly requested" — bundle
/// mismatch checks, optional request restriction — use this directly).
pub fn method_flag_opt(rest: &[String]) -> Result<Option<Method>, String> {
    match flag(rest, "--method")? {
        None => Ok(None),
        Some(s) => match Method::parse(&s) {
            Some(m) => Ok(Some(m)),
            None => Err(format!("unknown method '{s}' (lasso|rf|gbdt|mlp)")),
        },
    }
}

pub fn method_flag(rest: &[String], default: Method) -> Result<Method, String> {
    Ok(method_flag_opt(rest)?.unwrap_or(default))
}

pub fn mode_flag(rest: &[String]) -> Result<DeductionMode, String> {
    match flag(rest, "--mode")? {
        None => Ok(DeductionMode::Full),
        Some(s) => DeductionMode::parse(&s)
            .ok_or_else(|| format!("unknown mode '{s}' (full|nofusion|noselection)")),
    }
}

/// The scenario registry a subcommand resolves against: the builtin
/// devices plus every `--device-spec FILE.json` (repeatable) registered on
/// top, then every `--workload-spec FILE.json` (repeatable) qualifying the
/// whole SoC universe — devices first, so a workload qualifies custom SoCs
/// too. Errors name the offending file.
pub fn registry_flag(rest: &[String]) -> Result<Registry, String> {
    let mut reg = Registry::with_builtin();
    for path in flag_all(rest, "--device-spec")? {
        reg.load_spec_file(&path).map_err(|e| e.to_string())?;
    }
    for path in flag_all(rest, "--workload-spec")? {
        reg.load_workload_file(&path).map_err(|e| e.to_string())?;
    }
    Ok(reg)
}

/// The single required `--scenario ID`, resolved against the given
/// registry (builtin + any `--device-spec` registrations). Hands out the
/// registry's shared `Arc` — no per-flag `Scenario` clone.
pub fn scenario_flag(rest: &[String], reg: &Registry) -> Result<Arc<Scenario>, String> {
    let id = flag(rest, "--scenario")?
        .ok_or("need --scenario ID (see `edgelat list scenarios`)")?;
    reg.by_id(&id)
        .ok_or_else(|| format!("unknown scenario '{id}' (see `edgelat list scenarios`)"))
}

/// A comma-separated scenario list (`--scenario A,B,C`), each id resolved
/// and order preserved. Duplicates are rejected — the search would
/// otherwise silently double-count a device.
pub fn scenario_list_flag(rest: &[String], reg: &Registry) -> Result<Vec<Arc<Scenario>>, String> {
    let raw = flag(rest, "--scenario")?
        .ok_or("need --scenario ID[,ID...] (see `edgelat list scenarios`)")?;
    let mut out: Vec<Arc<Scenario>> = Vec::new();
    for id in raw.split(',').map(str::trim) {
        if id.is_empty() {
            return Err(format!("--scenario has an empty id in '{raw}'"));
        }
        if out.iter().any(|s| s.id == id) {
            return Err(format!("--scenario lists '{id}' twice"));
        }
        out.push(
            reg.by_id(id)
                .ok_or_else(|| format!("unknown scenario '{id}' (see `edgelat list scenarios`)"))?,
        );
    }
    Ok(out)
}

/// Parsed arguments of `edgelat transfer` (the adapt form; `transfer
/// eval` parses separately via [`transfer_eval_args`]).
pub struct TransferArgs {
    pub from_bundle: String,
    pub scenario_id: String,
    pub budget: usize,
    pub out: String,
    pub seed: u64,
    pub runs: usize,
}

/// `edgelat transfer --from-bundle SRC --to SCENARIO --budget K --out F`.
/// `--budget` defaults to 10 (MAPLE-Edge's few-shot regime) and must be
/// at least 1; `--out` picks the encoding by extension (`.bin` → binary).
pub fn transfer_args(rest: &[String]) -> Result<TransferArgs, String> {
    let from_bundle = flag(rest, "--from-bundle")?
        .ok_or("need --from-bundle FILE (a trained predictor bundle)")?;
    let scenario_id =
        flag(rest, "--to")?.ok_or("need --to SCENARIO (see `edgelat list scenarios`)")?;
    let budget = usize_flag(rest, "--budget", 10)?;
    if budget == 0 {
        return Err("--budget needs at least one target profile".into());
    }
    let out = flag(rest, "--out")?.ok_or("need --out FILE (.json or .bin)")?;
    Ok(TransferArgs {
        from_bundle,
        scenario_id,
        budget,
        out,
        seed: seed_flag(rest)?,
        runs: runs_flag(rest)?,
    })
}

/// Parsed arguments of `edgelat transfer eval`.
pub struct TransferEvalArgs {
    pub quick: bool,
    pub seed: u64,
    pub threads: Option<usize>,
    pub out: Option<String>,
}

pub fn transfer_eval_args(rest: &[String]) -> Result<TransferEvalArgs, String> {
    Ok(TransferEvalArgs {
        quick: has(rest, "--quick"),
        seed: seed_flag(rest)?,
        threads: threads_flag(rest)?,
        out: flag(rest, "--out")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn flag_returns_value_or_absence() {
        let rest = args(&["--seed", "7", "--quick"]);
        assert_eq!(flag(&rest, "--seed").unwrap(), Some("7".into()));
        assert_eq!(flag(&rest, "--runs").unwrap(), None);
        assert!(has(&rest, "--quick"));
        assert!(!has(&rest, "--slow"));
    }

    #[test]
    fn present_flag_without_value_is_rejected() {
        let rest = args(&["--out", "x.json", "--seed"]);
        let err = flag(&rest, "--seed").unwrap_err();
        assert!(err.contains("--seed"), "{err}");
        assert!(seed_flag(&rest).is_err());
    }

    #[test]
    fn numeric_flags_parse_and_default() {
        let rest = args(&["--seed", "99", "--train", "10", "--runs", "3"]);
        assert_eq!(seed_flag(&rest).unwrap(), 99);
        assert_eq!(train_flag(&rest).unwrap(), 10);
        assert_eq!(runs_flag(&rest).unwrap(), 3);
        let none = args(&[]);
        assert_eq!(seed_flag(&none).unwrap(), DEFAULT_SEED);
        assert_eq!(train_flag(&none).unwrap(), DEFAULT_TRAIN);
        assert_eq!(runs_flag(&none).unwrap(), DEFAULT_RUNS);
    }

    #[test]
    fn bad_numeric_inputs_are_rejected_not_defaulted() {
        for bad in ["abc", "-5", "1.5", ""] {
            let rest = args(&["--seed", bad]);
            let err = seed_flag(&rest).unwrap_err();
            assert!(err.contains("--seed"), "{bad}: {err}");
        }
        assert!(train_flag(&args(&["--train", "0"])).is_err());
        assert!(runs_flag(&args(&["--runs", "0"])).is_err());
    }

    #[test]
    fn threads_zero_clamps_to_one_worker() {
        // The documented edge case: `--threads 0` is not an error and not
        // a zero-worker pool — it resolves to one worker, the same
        // clamping `ExecPool::new(0)` applies.
        assert_eq!(threads_flag(&args(&["--threads", "0"])).unwrap(), Some(1));
        assert_eq!(threads_flag(&args(&["--threads", "4"])).unwrap(), Some(4));
        assert_eq!(threads_flag(&args(&[])).unwrap(), None);
        assert!(threads_flag(&args(&["--threads", "many"])).is_err());
        assert!(threads_flag(&args(&["--threads"])).is_err());
    }

    #[test]
    fn method_and_mode_flags() {
        let rf = method_flag(&args(&["--method", "rf"]), Method::Gbdt).unwrap();
        assert_eq!(rf, Method::RandomForest);
        assert_eq!(method_flag(&args(&[]), Method::Gbdt).unwrap(), Method::Gbdt);
        assert!(method_flag(&args(&["--method", "svm"]), Method::Gbdt).is_err());
        // The optional variant distinguishes absent from defaulted.
        assert_eq!(method_flag_opt(&args(&[])).unwrap(), None);
        assert_eq!(method_flag_opt(&args(&["--method", "lasso"])).unwrap(), Some(Method::Lasso));
        assert!(method_flag_opt(&args(&["--method", "svm"])).is_err());
        assert_eq!(mode_flag(&args(&["--mode", "nofusion"])).unwrap(), DeductionMode::NoFusion);
        assert!(mode_flag(&args(&["--mode", "??"])).is_err());
    }

    #[test]
    fn budget_flag_requires_positive_finite() {
        let b = positive_f64_flag(&args(&["--budget", "55.5"]), "--budget").unwrap();
        assert_eq!(b, Some(55.5));
        assert_eq!(positive_f64_flag(&args(&[]), "--budget").unwrap(), None);
        for bad in ["-1", "0", "nan", "inf", "soon"] {
            assert!(
                positive_f64_flag(&args(&["--budget", bad]), "--budget").is_err(),
                "{bad} accepted"
            );
        }
    }

    #[test]
    fn addr_flag_parses_and_defaults() {
        let d = addr_flag(&args(&[]), "127.0.0.1:0").unwrap();
        assert_eq!(d, "127.0.0.1:0".parse().unwrap());
        let a = addr_flag(&args(&["--addr", "0.0.0.0:7878"]), "127.0.0.1:0").unwrap();
        assert_eq!(a.port(), 7878);
        // Hostnames and garbage are rejected with the expected shape named
        // (std SocketAddr parsing is numeric-only — no DNS on the daemon).
        for bad in ["localhost:10", "7878", "1.2.3.4", "1.2.3.4:notaport", ""] {
            let err = addr_flag(&args(&["--addr", bad]), "127.0.0.1:0").unwrap_err();
            assert!(err.contains("IP:PORT"), "{bad}: {err}");
        }
        assert!(addr_flag(&args(&["--addr"]), "127.0.0.1:0").is_err());
    }

    #[test]
    fn scenario_flags_resolve_against_the_registry() {
        let reg = Registry::builtin();
        let sc = scenario_flag(&args(&["--scenario", "HelioP35/gpu"]), reg).unwrap();
        assert_eq!(sc.id, "HelioP35/gpu");
        assert!(scenario_flag(&args(&["--scenario", "Nope/gpu"]), reg).is_err());
        assert!(scenario_flag(&args(&[]), reg).is_err());
        let list =
            scenario_list_flag(&args(&["--scenario", "HelioP35/gpu,Snapdragon855/gpu"]), reg)
                .unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].id, "HelioP35/gpu");
        assert_eq!(list[1].id, "Snapdragon855/gpu");
        // Duplicates, empty segments, and unknown ids are rejected.
        assert!(
            scenario_list_flag(&args(&["--scenario", "HelioP35/gpu,HelioP35/gpu"]), reg).is_err()
        );
        assert!(scenario_list_flag(&args(&["--scenario", "HelioP35/gpu,,X"]), reg).is_err());
        assert!(scenario_list_flag(&args(&["--scenario", "X/gpu"]), reg).is_err());
    }

    #[test]
    fn flag_all_collects_every_occurrence() {
        let rest = args(&["--device-spec", "a.json", "--seed", "1", "--device-spec", "b.json"]);
        assert_eq!(flag_all(&rest, "--device-spec").unwrap(), vec!["a.json", "b.json"]);
        assert_eq!(flag_all(&args(&[]), "--device-spec").unwrap(), Vec::<String>::new());
        assert!(flag_all(&args(&["--device-spec"]), "--device-spec").is_err());
        let trailing = args(&["--device-spec", "a", "--device-spec"]);
        assert!(flag_all(&trailing, "--device-spec").is_err());
    }

    #[test]
    fn transfer_args_parse_and_validate() {
        let rest = args(&[
            "--from-bundle",
            "src.bin",
            "--to",
            "FleetSoc7n0/cpu/1L/fp32",
            "--budget",
            "10",
            "--out",
            "t.json",
        ]);
        let a = transfer_args(&rest).unwrap();
        assert_eq!(a.from_bundle, "src.bin");
        assert_eq!(a.scenario_id, "FleetSoc7n0/cpu/1L/fp32");
        assert_eq!(a.budget, 10);
        assert_eq!(a.out, "t.json");
        assert_eq!(a.seed, DEFAULT_SEED);
        assert_eq!(a.runs, DEFAULT_RUNS);
        // Budget defaults to the few-shot regime; zero is rejected.
        let minimal = args(&["--from-bundle", "s.json", "--to", "X/gpu", "--out", "o.bin"]);
        assert_eq!(transfer_args(&minimal).unwrap().budget, 10);
        let zero = args(&[
            "--from-bundle", "s.json", "--to", "X/gpu", "--out", "o.bin", "--budget", "0",
        ]);
        assert!(transfer_args(&zero).is_err());
        // Every required flag is required, each named in its error.
        for (missing, name) in [
            (args(&["--to", "X/gpu", "--out", "o"]), "--from-bundle"),
            (args(&["--from-bundle", "s", "--out", "o"]), "--to"),
            (args(&["--from-bundle", "s", "--to", "X/gpu"]), "--out"),
        ] {
            let err = transfer_args(&missing).unwrap_err();
            assert!(err.contains(name), "{name}: {err}");
        }
    }

    #[test]
    fn transfer_eval_args_parse() {
        let a = transfer_eval_args(&args(&["--quick", "--seed", "7", "--threads", "2"])).unwrap();
        assert!(a.quick);
        assert_eq!(a.seed, 7);
        assert_eq!(a.threads, Some(2));
        assert_eq!(a.out, None);
        let d = transfer_eval_args(&args(&["--out", "CURVE.json"])).unwrap();
        assert!(!d.quick);
        assert_eq!(d.seed, DEFAULT_SEED);
        assert_eq!(d.out, Some("CURVE.json".into()));
        assert!(transfer_eval_args(&args(&["--seed"])).is_err());
    }

    #[test]
    fn registry_flag_loads_device_specs() {
        // No flag: exactly the builtin universe.
        let reg = registry_flag(&args(&[])).unwrap();
        assert_eq!(reg.scenario_count(), 72);
        // A missing file errors, naming the path.
        let err = registry_flag(&args(&["--device-spec", "/no/such/spec.json"])).unwrap_err();
        assert!(err.contains("/no/such/spec.json"), "{err}");
        // A real spec file extends the universe and its scenarios resolve.
        let mut spec = crate::device::builtin_specs()[3].clone();
        spec.soc.name = "CliTestSoc".into();
        let path = std::env::temp_dir()
            .join(format!("edgelat_cli_spec_{}.json", std::process::id()));
        std::fs::write(&path, spec.to_json().to_string()).unwrap();
        let rest = args(&["--device-spec", path.to_str().unwrap()]);
        let reg = registry_flag(&rest).unwrap();
        assert_eq!(reg.soc_count(), 5);
        let sc = scenario_flag(
            &args(&["--device-spec", path.to_str().unwrap(), "--scenario", "CliTestSoc/gpu"]),
            &reg,
        )
        .unwrap();
        assert_eq!(sc.soc.name, "CliTestSoc");
        // An invalid spec file errors, naming the path.
        std::fs::write(&path, "{}").unwrap();
        let err = registry_flag(&rest).unwrap_err();
        assert!(err.contains("edgelat_cli_spec"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn registry_flag_loads_workload_specs() {
        // A workload spec qualifies the whole universe: 72 x (1 + 1).
        let wl = crate::workload::builtin_presets()[0].clone();
        let path = std::env::temp_dir()
            .join(format!("edgelat_cli_wl_{}.json", std::process::id()));
        std::fs::write(&path, wl.to_json().to_string()).unwrap();
        let rest = args(&["--workload-spec", path.to_str().unwrap()]);
        let reg = registry_flag(&rest).unwrap();
        assert_eq!(reg.scenario_count(), 144);
        assert_eq!(reg.contended_count(), 72);
        let sc = scenario_flag(
            &args(&["--scenario", &format!("HelioP35/gpu@{}", wl.name)]),
            &reg,
        )
        .unwrap();
        assert_eq!(sc.workload.as_ref().unwrap().name, wl.name);
        // Workloads load after device specs regardless of flag order, so
        // custom SoCs are qualified too.
        let mut spec = crate::device::builtin_specs()[3].clone();
        spec.soc.name = "CliWlSoc".into();
        let spec_path = std::env::temp_dir()
            .join(format!("edgelat_cli_wl_spec_{}.json", std::process::id()));
        std::fs::write(&spec_path, spec.to_json().to_string()).unwrap();
        let both = args(&[
            "--workload-spec",
            path.to_str().unwrap(),
            "--device-spec",
            spec_path.to_str().unwrap(),
        ]);
        let reg = registry_flag(&both).unwrap();
        assert!(reg.by_id(&format!("CliWlSoc/gpu@{}", wl.name)).is_some());
        // Missing and invalid files error, naming the path.
        let err = registry_flag(&args(&["--workload-spec", "/no/such/wl.json"])).unwrap_err();
        assert!(err.contains("/no/such/wl.json"), "{err}");
        std::fs::write(&path, "{}").unwrap();
        let err = registry_flag(&rest).unwrap_err();
        assert!(err.contains("edgelat_cli_wl"), "{err}");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&spec_path);
    }
}
