//! Kernel selection — Algorithm C.2 (TFLite GPU delegate): per-convolution
//! choice among {GroupedConv2D, Winograd, Conv2D}, with hardware-dependent
//! thresholds (Adreno is stricter than Mali/PowerVR; Table 2 of the paper).

use crate::graph::{Graph, Op, OpType};
use crate::tflite::fusion::FusedKernel;
use crate::tflite::CompileOptions;

/// GPU vendor families distinguished by TFLite's kernel-selection rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuKind {
    /// Adreno 600-series (both Adreno 640 and 616 in the paper's devices).
    Adreno6xx,
    /// Other Adreno generations.
    Adreno,
    Mali,
    PowerVR,
    /// Present in TFLite's rule set; unused by the paper's devices.
    Amd,
}

impl GpuKind {
    pub fn is_adreno(&self) -> bool {
        matches!(self, GpuKind::Adreno6xx | GpuKind::Adreno)
    }
    pub fn name(&self) -> &'static str {
        match self {
            GpuKind::Adreno6xx => "Adreno6xx",
            GpuKind::Adreno => "Adreno",
            GpuKind::Mali => "Mali",
            GpuKind::PowerVR => "PowerVR",
            GpuKind::Amd => "AMD",
        }
    }

    /// Inverse of [`name`](Self::name), for device-spec files and bundle
    /// descriptors. Case-insensitive.
    pub fn parse(s: &str) -> Option<GpuKind> {
        match s.to_ascii_lowercase().as_str() {
            "adreno6xx" => Some(GpuKind::Adreno6xx),
            "adreno" => Some(GpuKind::Adreno),
            "mali" => Some(GpuKind::Mali),
            "powervr" => Some(GpuKind::PowerVR),
            "amd" => Some(GpuKind::Amd),
            _ => None,
        }
    }
}

/// The implementation chosen for a compiled kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelImpl {
    Conv2D,
    Winograd,
    /// Optimized single-kernel grouped convolution.
    GroupedConv2D,
    /// Naive grouped convolution: split + per-group Conv2D + concat.
    NaiveGroupedConv2D { groups: usize },
    DepthwiseConv2D,
    FullyConnected,
    /// Any non-convolution kernel; costed by the root op's type.
    Generic,
}

impl KernelImpl {
    pub fn name(&self) -> &'static str {
        match self {
            KernelImpl::Conv2D => "Conv2D",
            KernelImpl::Winograd => "Winograd",
            KernelImpl::GroupedConv2D => "GroupedConv2D",
            KernelImpl::NaiveGroupedConv2D { .. } => "NaiveGroupedConv2D",
            KernelImpl::DepthwiseConv2D => "DepthwiseConv2D",
            KernelImpl::FullyConnected => "FullyConnected",
            KernelImpl::Generic => "Generic",
        }
    }

    /// The op-type bucket whose latency predictor handles this kernel
    /// (Winograd and Conv2D get *separate* predictors — Section 5.4).
    pub fn predictor_bucket(&self, root_type: OpType) -> &'static str {
        match self {
            KernelImpl::Conv2D => "Conv2D",
            KernelImpl::Winograd => "Winograd",
            KernelImpl::GroupedConv2D => "GroupedConv2D",
            KernelImpl::NaiveGroupedConv2D { .. } => "NaiveGroupedConv2D",
            KernelImpl::DepthwiseConv2D => "DepthwiseConv2D",
            KernelImpl::FullyConnected => "FullyConnected",
            KernelImpl::Generic => root_type.name(),
        }
    }
}

/// Convolution parameters extracted for the selection rules.
#[derive(Debug, Clone, Copy)]
pub struct ConvInfo {
    pub input_channel: usize,
    pub output_channel: usize,
    pub output_height: usize,
    pub output_width: usize,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub stride: usize,
    pub groups: usize,
}

/// `CheckGroupedConv2D` (Algorithm C.2 lines 6-10, implemented literally:
/// `src_group_size = op_info.input_channel`,
/// `dst_group_size = op_info.output_channel / op_info.group`).
pub fn check_grouped_conv2d(info: &ConvInfo) -> bool {
    if info.groups == 1 {
        return false;
    }
    let src_group_size = info.input_channel;
    let dst_group_size = info.output_channel / info.groups;
    src_group_size % 4 == 0 && dst_group_size % 4 == 0
}

/// `CheckWinograd` (Algorithm C.2 lines 11-28).
pub fn check_winograd(gpu: GpuKind, info: &ConvInfo) -> bool {
    if info.groups != 1 || info.kernel_h != 3 || info.kernel_w != 3 || info.stride != 1 {
        return false;
    }
    let src_depth = info.input_channel.div_ceil(4);
    let dst_depth = info.output_channel.div_ceil(4);
    match gpu {
        g if g.is_adreno() => {
            if src_depth < 32 || dst_depth < 32 {
                return false;
            }
        }
        GpuKind::Amd => {
            if src_depth < 16 || dst_depth < 8 {
                return false;
            }
        }
        _ => {
            if src_depth < 16 || dst_depth < 16 {
                return false;
            }
        }
    }
    let total_tiles = info.output_height.div_ceil(4) * info.output_width.div_ceil(4);
    match gpu {
        GpuKind::Adreno6xx => total_tiles >= 128,
        GpuKind::Adreno => total_tiles >= 64,
        _ => total_tiles >= 32,
    }
}

/// `SelectConv2DKernel` (Algorithm C.2 lines 1-5).
pub fn select_conv_kernel(gpu: GpuKind, info: &ConvInfo, options: CompileOptions) -> KernelImpl {
    if info.groups > 1 {
        if options.grouped && check_grouped_conv2d(info) {
            return KernelImpl::GroupedConv2D;
        }
        return KernelImpl::NaiveGroupedConv2D { groups: info.groups };
    }
    if options.winograd && check_winograd(gpu, info) {
        return KernelImpl::Winograd;
    }
    KernelImpl::Conv2D
}

/// Extract `ConvInfo` from a graph node (convolutions only).
pub fn conv_info(g: &Graph, op_id: usize) -> Option<ConvInfo> {
    let node = &g.nodes[op_id];
    match node.op {
        Op::Conv2D { kh, kw, stride, out_c, groups, .. } => {
            let i = g.shape(node.inputs[0]);
            let o = g.shape(node.outputs[0]);
            Some(ConvInfo {
                input_channel: i.c,
                output_channel: out_c,
                output_height: o.h,
                output_width: o.w,
                kernel_h: kh,
                kernel_w: kw,
                stride,
                groups,
            })
        }
        _ => None,
    }
}

/// Assign the kernel implementation for a fused kernel based on its root op.
pub fn select_for_kernel(
    g: &Graph,
    k: &FusedKernel,
    gpu: GpuKind,
    options: CompileOptions,
) -> KernelImpl {
    let root = &g.nodes[k.root()];
    match &root.op {
        Op::Conv2D { .. } => {
            let info = conv_info(g, k.root()).unwrap();
            select_conv_kernel(gpu, &info, options)
        }
        Op::DepthwiseConv2D { .. } => KernelImpl::DepthwiseConv2D,
        Op::FullyConnected { .. } => KernelImpl::FullyConnected,
        _ => KernelImpl::Generic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(in_c: usize, out_c: usize, out_h: usize) -> ConvInfo {
        ConvInfo {
            input_channel: in_c,
            output_channel: out_c,
            output_height: out_h,
            output_width: out_h,
            kernel_h: 3,
            kernel_w: 3,
            stride: 1,
            groups: 1,
        }
    }

    /// Table 2 of the paper: three ResNet16 convolutions.
    #[test]
    fn table2_row1() {
        // in=64 out=64 out_h=56: src/dst_depth=16, total_tiles=196.
        let i = info(64, 64, 56);
        assert!(!check_winograd(GpuKind::Adreno6xx, &i)); // depth < 32
        assert!(check_winograd(GpuKind::Mali, &i));
        assert!(check_winograd(GpuKind::PowerVR, &i));
    }

    #[test]
    fn table2_row2() {
        // in=128 out=128 out_h=28: depth=32, total_tiles=49.
        let i = info(128, 128, 28);
        assert!(!check_winograd(GpuKind::Adreno6xx, &i)); // tiles < 128
        assert!(check_winograd(GpuKind::Mali, &i));
    }

    #[test]
    fn table2_row3() {
        // in=256 out=256 out_h=14: depth=64, total_tiles=16 < 32.
        let i = info(256, 256, 14);
        assert!(!check_winograd(GpuKind::Adreno6xx, &i));
        assert!(!check_winograd(GpuKind::Mali, &i));
        assert!(!check_winograd(GpuKind::PowerVR, &i));
    }

    #[test]
    fn winograd_requires_3x3_stride1_group1() {
        let mut i = info(128, 128, 56);
        assert!(check_winograd(GpuKind::Mali, &i));
        i.stride = 2;
        assert!(!check_winograd(GpuKind::Mali, &i));
        i.stride = 1;
        i.kernel_h = 5;
        i.kernel_w = 5;
        assert!(!check_winograd(GpuKind::Mali, &i));
        i.kernel_h = 3;
        i.kernel_w = 3;
        i.groups = 2;
        assert!(!check_winograd(GpuKind::Mali, &i));
    }

    #[test]
    fn amd_thresholds() {
        // AMD: src_depth >= 16, dst_depth >= 8.
        let i = info(64, 32, 56);
        assert!(check_winograd(GpuKind::Amd, &i));
        assert!(!check_winograd(GpuKind::Mali, &i)); // dst_depth 8 < 16
    }

    #[test]
    fn grouped_check_requires_mult4_group_sizes() {
        let mut i = info(64, 64, 28);
        i.groups = 4; // group sizes 16/16 -> optimized
        assert!(check_grouped_conv2d(&i));
        i.groups = 8; // 8/8 -> ok
        assert!(check_grouped_conv2d(&i));
        let mut j = info(24, 24, 28);
        j.groups = 2; // 12/12 -> ok
        assert!(check_grouped_conv2d(&j));
        let mut k = info(6, 6, 28);
        k.groups = 2; // 3/3 -> not multiple of 4
        assert!(!check_grouped_conv2d(&k));
    }

    #[test]
    fn select_priority_grouped_over_winograd() {
        let mut i = info(128, 128, 56);
        i.groups = 4;
        let k = select_conv_kernel(GpuKind::Mali, &i, CompileOptions::default());
        assert_eq!(k, KernelImpl::GroupedConv2D);
    }

    #[test]
    fn options_disable_optimizations() {
        let i = info(128, 128, 56);
        let no_wino = CompileOptions { winograd: false, ..Default::default() };
        assert_eq!(select_conv_kernel(GpuKind::Mali, &i, no_wino), KernelImpl::Conv2D);
        let mut gi = info(64, 64, 28);
        gi.groups = 4;
        let no_grp = CompileOptions { grouped: false, ..Default::default() };
        assert_eq!(
            select_conv_kernel(GpuKind::Mali, &gi, no_grp),
            KernelImpl::NaiveGroupedConv2D { groups: 4 }
        );
    }

    #[test]
    fn resnet16_winograd_on_mali_not_adreno() {
        // End-to-end: the paper observes Winograd on Mali G76 but never on
        // Adreno 640 for the zoo (Section 3.2.2 / Fig 11).
        let g = crate::zoo::resnets::resnet(16, 1.0);
        let count = |gpu: GpuKind| {
            g.nodes
                .iter()
                .filter_map(|n| conv_info(&g, n.id))
                .filter(|i| check_winograd(gpu, i))
                .count()
        };
        assert!(count(GpuKind::Mali) > 0);
        assert_eq!(count(GpuKind::Adreno6xx), 0);
    }
}
