//! Kernel fusion — a faithful implementation of Algorithm C.1 (TFLite GPU
//! delegate, `gpu_model.cc` `MergeNodes`).
//!
//! Two consecutive operations fuse when:
//! 1. the first has exactly one output tensor,
//! 2. the second is the only consumer of that tensor,
//! 3. the second uses it as its *first* input and produces a single output,
//! 4. the second is "linkable" (an activation or element-wise op).
//!
//! Fusion chains: `conv -> add -> relu` collapses into one kernel rooted at
//! the convolution. Extra inputs of fused binary ops (e.g. the residual
//! shortcut of an ADD) become extra inputs of the fused kernel.

use crate::graph::{Graph, OpId, TensorId};
use crate::tflite::select::KernelImpl;
use std::collections::HashSet;

/// A (possibly fused) GPU kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedKernel {
    /// All original graph ops in this kernel, in execution order. The first
    /// is the kernel "root" whose cost dominates.
    pub ops: Vec<OpId>,
    /// All input tensors read by the kernel (root inputs first).
    pub src: Vec<TensorId>,
    /// Output tensors produced.
    pub dst: Vec<TensorId>,
    /// Kernel implementation; assigned by `select::select_for_kernel`.
    pub impl_: KernelImpl,
}

impl FusedKernel {
    /// The root op id (cost-dominant op of the kernel).
    pub fn root(&self) -> OpId {
        self.ops[0]
    }

    /// Ops other than the root that were fused in.
    pub fn fused_ops(&self) -> &[OpId] {
        &self.ops[1..]
    }
}

/// The trivially-compiled graph: one kernel per node (fusion disabled).
pub fn no_fuse(g: &Graph) -> Vec<FusedKernel> {
    g.nodes
        .iter()
        .map(|n| FusedKernel {
            ops: vec![n.id],
            src: n.inputs.clone(),
            dst: n.outputs.clone(),
            impl_: KernelImpl::Generic,
        })
        .collect()
}

/// Algorithm C.1: single pass over the nodes in topological order, merging
/// each node into its unique linkable consumer where the conditions hold.
pub fn fuse(g: &Graph) -> Vec<FusedKernel> {
    merge_pass(g, no_fuse(g))
}

/// One `MergeNodes` pass over an existing kernel list. `fuse` is
/// `merge_pass(g, no_fuse(g))`; exposing the pass itself lets the
/// integration property tests assert it is a **fixpoint** — running it
/// again over an already-merged list changes nothing (greedy chain
/// absorption along the visit order leaves no mergeable pair behind).
pub fn merge_pass(g: &Graph, kernels: Vec<FusedKernel>) -> Vec<FusedKernel> {
    // Virtual node list, initially one per input kernel.
    let mut vnodes: Vec<Option<FusedKernel>> = kernels.into_iter().map(Some).collect();
    // Map tensor -> index of the vnode that currently *consumes-as-merged* …
    // simpler: we mimic the algorithm directly over the vnode list.
    let mut ready: HashSet<TensorId> = g.inputs.iter().copied().collect();
    let order: Vec<usize> = (0..vnodes.len()).collect();

    for &ci in &order {
        // cur_node may have been merged away already (it cannot: merging
        // removes cur, and cur is visited once) — but it may have absorbed
        // earlier nodes. Skip removed entries.
        let Some(cur) = vnodes[ci].clone() else { continue };
        for &d in &cur.dst {
            ready.insert(d);
        }
        // (1) single output tensor
        if cur.dst.len() != 1 {
            continue;
        }
        let out = cur.dst[0];
        // Find candidate consumers among the *remaining* vnodes.
        let mut candidates: Vec<(usize, usize)> = Vec::new(); // (vnode idx, input pos)
        for (ni, vn) in vnodes.iter().enumerate() {
            let Some(vn) = vn else { continue };
            if ni == ci {
                continue;
            }
            for (k, &s) in vn.src.iter().enumerate() {
                if s == out {
                    candidates.push((ni, k));
                }
            }
        }
        // (2) exactly one consumer, (3) consuming at input position 0
        if candidates.len() != 1 || candidates[0].1 != 0 {
            continue;
        }
        let (ni, _) = candidates[0];
        let next = vnodes[ni].as_ref().unwrap();
        // (3b) next produces a single output, (4) next is linkable, and its
        // first input is ready (true by construction, kept for fidelity).
        let next_root_linkable = is_linkable(g, next);
        if !(next.dst.len() == 1 && next_root_linkable && ready.contains(&next.src[0])) {
            continue;
        }
        // Merge(cur, next): next absorbs cur — fused kernel executes cur's
        // ops then next's; reads cur's inputs plus next's non-fused inputs.
        let mut merged_ops = cur.ops.clone();
        merged_ops.extend(next.ops.iter().copied());
        let mut merged_src = cur.src.clone();
        merged_src.extend(next.src.iter().copied().filter(|&s| s != out));
        let merged = FusedKernel {
            ops: merged_ops,
            src: merged_src,
            dst: next.dst.clone(),
            impl_: KernelImpl::Generic,
        };
        vnodes[ni] = Some(merged);
        vnodes[ci] = None; // nodes.remove(cur_node)
    }

    vnodes.into_iter().flatten().collect()
}

/// `IsLinkable` for a (possibly already merged) vnode: TFLite checks the
/// type of the candidate *node*, and a merged vnode's type is its root
/// op's type (the cost-dominant op everything else was linked onto).
/// During the first pass the distinction is invisible — when a producer is
/// visited, its position-0 consumer is always still unmerged (for the
/// consumer to be merged already, the node absorbed into it would have to
/// sit upstream of the producer being visited, which contradicts the
/// visit order) — but checking the root is what
/// makes the pass a fixpoint: a chain kernel like `[conv, relu]` must not
/// be absorbable into a predecessor just because it *ends* in a linkable
/// op. `tests/fusion_properties.rs` asserts the fixpoint across the NAS
/// space.
fn is_linkable(g: &Graph, vn: &FusedKernel) -> bool {
    if vn.dst.len() != 1 {
        return false;
    }
    g.nodes[vn.root()].op.is_linkable()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ActKind, EwKind, GraphBuilder};

    #[test]
    fn conv_relu_fuses() {
        let mut b = GraphBuilder::new("t", 8, 8, 4);
        let x = b.input_tensor();
        let t = b.conv_act(x, 8, 3, 1, ActKind::Relu);
        let g = b.finish(vec![t]);
        let ks = fuse(&g);
        assert_eq!(ks.len(), 1);
        assert_eq!(ks[0].ops, vec![0, 1]);
    }

    #[test]
    fn chain_conv_add_relu_fuses_into_one() {
        // conv -> (+shortcut) -> relu : the classic residual tail.
        let mut b = GraphBuilder::new("t", 8, 8, 8);
        let x = b.input_tensor();
        let y = b.conv(x, 8, 3, 1, crate::graph::Padding::Same);
        let t = b.add_t(y, x);
        let t = b.relu(t);
        let g = b.finish(vec![t]);
        let ks = fuse(&g);
        assert_eq!(ks.len(), 1, "{ks:?}");
        assert_eq!(ks[0].ops, vec![0, 1, 2]);
        // Fused kernel reads conv's input and the shortcut.
        assert!(ks[0].src.contains(&x));
    }

    #[test]
    fn two_consumers_block_fusion() {
        // conv output feeds both a relu and a second conv -> no fusion of
        // the first conv (condition 2).
        let mut b = GraphBuilder::new("t", 8, 8, 4);
        let x = b.input_tensor();
        let y = b.conv(x, 8, 3, 1, crate::graph::Padding::Same);
        let r = b.relu(y);
        let z = b.conv(y, 8, 3, 1, crate::graph::Padding::Same);
        let t = b.add_t(r, z);
        let g = b.finish(vec![t]);
        let ks = fuse(&g);
        // conv1 unfused; relu unfused (its producer had 2 consumers);
        // conv2 + add fuse (add's first input is relu's output? no —
        // add(r, z): first input r). So conv2 can't fuse into add either.
        // relu -> add fuses (add's first input is r, relu single consumer).
        let total_ops: usize = ks.iter().map(|k| k.ops.len()).sum();
        assert_eq!(total_ops, 4);
        assert!(ks.len() < 4, "at least one fusion should happen: {ks:?}");
    }

    #[test]
    fn second_input_position_blocks_fusion() {
        // add(a, b) where the producer's output is the SECOND input: no fuse.
        let mut b = GraphBuilder::new("t", 8, 8, 4);
        let x = b.input_tensor();
        let a = b.ew_const(EwKind::Abs, x);
        let c = b.ew(EwKind::Add, x, a); // a is input position 1
        let g = b.finish(vec![c]);
        let ks = fuse(&g);
        assert_eq!(ks.len(), 2, "{ks:?}");
    }

    #[test]
    fn split_multiple_outputs_never_fuse() {
        let mut b = GraphBuilder::new("t", 8, 8, 8);
        let x = b.input_tensor();
        let parts = b.split(x, 2);
        let a = b.ew_const(EwKind::Abs, parts[0]);
        let n = b.ew_const(EwKind::Neg, parts[1]);
        let t = b.concat(vec![a, n]);
        let g = b.finish(vec![t]);
        let ks = fuse(&g);
        // split can't fuse (2 outputs); abs/neg fuse into… concat is not
        // linkable, so abs/neg stay. 4 kernels total.
        assert_eq!(ks.len(), 4);
    }

    #[test]
    fn fusion_preserves_op_multiset() {
        // Property: every original op appears in exactly one kernel.
        let g = crate::zoo::mobilenets::mobilenet_v2(0.5);
        let ks = fuse(&g);
        let mut seen: Vec<OpId> = ks.iter().flat_map(|k| k.ops.iter().copied()).collect();
        seen.sort_unstable();
        let expect: Vec<OpId> = (0..g.nodes.len()).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn fusion_only_absorbs_linkables() {
        let g = crate::zoo::resnets::resnet(18, 1.0);
        for k in fuse(&g) {
            for &op in k.fused_ops() {
                assert!(
                    g.nodes[op].op.is_linkable(),
                    "non-linkable {:?} was fused",
                    g.nodes[op].op
                );
            }
        }
    }

    #[test]
    fn fusion_reduces_kernels_substantially_on_zoo() {
        // Paper Fig 6a: >45% kernel-count reduction on state-of-the-art NAs.
        let g = crate::zoo::mobilenets::mobilenet_v2(1.0);
        let fused = fuse(&g).len();
        let unfused = g.nodes.len();
        let reduction = 1.0 - fused as f64 / unfused as f64;
        assert!(reduction > 0.30, "reduction {reduction:.2}");
    }
}
