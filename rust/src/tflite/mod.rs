//! Simulation of TFLite GPU-delegate model compilation: kernel **fusion**
//! (Algorithm C.1 of the paper, from `gpu_model.cc`) and kernel **selection**
//! (Algorithm C.2: Winograd and GroupedConv2D applicability).
//!
//! This module is used twice, mirroring the paper's methodology:
//! 1. inside the device simulator (`device::gpu`) as the *ground truth*
//!    compilation a phone would perform, and
//! 2. inside the prediction framework (`framework`) as the *kernel
//!    deduction* step (Section 4.1) that predicts — without a device —
//!    which kernels will run.

pub mod fusion;
pub mod select;

pub use fusion::{fuse, FusedKernel};
pub use select::{select_conv_kernel, GpuKind, KernelImpl};

use crate::graph::Graph;

/// Compilation options; the ablation benches (Figs 6, 8, 9, 19, 20) disable
/// individual optimizations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompileOptions {
    /// Apply Algorithm C.1 kernel fusion.
    pub fusion: bool,
    /// Allow Winograd kernels where Algorithm C.2 admits them.
    pub winograd: bool,
    /// Allow the optimized single-kernel GroupedConv2D implementation.
    pub grouped: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions { fusion: true, winograd: true, grouped: true }
    }
}

/// A GPU-compiled graph: the list of kernels actually dispatched.
#[derive(Debug, Clone)]
pub struct CompiledGraph {
    pub kernels: Vec<FusedKernel>,
    pub gpu: GpuKind,
    pub options: CompileOptions,
}

/// Compile a graph for a GPU: fuse linkable ops, then select a kernel
/// implementation for each convolution.
pub fn compile(g: &Graph, gpu: GpuKind, options: CompileOptions) -> CompiledGraph {
    let mut kernels = if options.fusion {
        fuse(g)
    } else {
        fusion::no_fuse(g)
    };
    for k in &mut kernels {
        k.impl_ = select::select_for_kernel(g, k, gpu, options);
    }
    CompiledGraph { kernels, gpu, options }
}

impl CompiledGraph {
    /// Number of OpenCL kernel dispatches (naive grouped convolutions cost
    /// `groups + 2` dispatches: per-group convs plus split and concat).
    pub fn dispatch_count(&self) -> usize {
        self.kernels
            .iter()
            .map(|k| match k.impl_ {
                KernelImpl::NaiveGroupedConv2D { groups } => groups + 2,
                _ => 1,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ActKind, GraphBuilder, Padding};

    #[test]
    fn compile_reduces_kernels_vs_nodes() {
        let mut b = GraphBuilder::new("t", 32, 32, 8);
        let x = b.input_tensor();
        let t = b.conv_act(x, 16, 3, 1, ActKind::Relu);
        let t = b.conv_act(t, 16, 3, 1, ActKind::Relu);
        let g = b.finish(vec![t]);
        let c = compile(&g, GpuKind::Mali, CompileOptions::default());
        assert_eq!(c.kernels.len(), 2); // two conv+relu fused kernels
        let c0 = compile(&g, GpuKind::Mali, CompileOptions { fusion: false, ..Default::default() });
        assert_eq!(c0.kernels.len(), 4);
    }

    #[test]
    fn default_options_enable_everything() {
        let o = CompileOptions::default();
        assert!(o.fusion && o.winograd && o.grouped);
    }

    #[test]
    fn dispatch_count_counts_naive_grouped() {
        let mut b = GraphBuilder::new("t", 16, 16, 18);
        let x = b.input_tensor();
        // groups=3: dst_group_size = 18/3 = 6, not a multiple of 4 -> naive.
        let t = b.grouped_conv(x, 18, 3, 1, 3);
        let g = b.finish(vec![t]);
        let c = compile(&g, GpuKind::Mali, CompileOptions::default());
        assert_eq!(c.kernels.len(), 1);
        assert_eq!(c.dispatch_count(), 5); // 3 convs + split + concat
    }

    #[test]
    fn conv_padding_never_affects_compile() {
        let mut b = GraphBuilder::new("t", 32, 32, 8);
        let x = b.input_tensor();
        let t = b.conv(x, 16, 3, 1, Padding::Valid);
        let g = b.finish(vec![t]);
        let c = compile(&g, GpuKind::PowerVR, CompileOptions::default());
        assert_eq!(c.kernels.len(), 1);
    }
}
