//! System performance benches (cargo bench --bench pipeline).
//!
//! No criterion in the offline crate set, so this is a plain harness =
//! false binary: warmup + N timed iterations, reporting mean/min per op.
//! These cover the L3 hot paths targeted by the §Perf pass in
//! EXPERIMENTS.md: graph construction, fusion, kernel selection, feature
//! extraction, the device simulator, profiling throughput, and predictor
//! train/inference.

use edgelat::device::{DataRep, Target};
use edgelat::exec_pool::ExecPool;
use edgelat::predict::{train, Method};
use edgelat::profiler::{bucket_datasets, profile_set, profile_set_with};
use edgelat::scenario::{one_large_core, Scenario};
use edgelat::tflite::{compile, CompileOptions};
use edgelat::util::timing::time_named;

fn bench<F: FnMut()>(name: &str, iters: usize, f: F) {
    println!("{}", time_named(name, iters, f).render());
}

fn main() {
    println!("== edgelat pipeline benches ==");
    let mv2 = edgelat::zoo::mobilenets::mobilenet_v2(1.0);
    let r18 = edgelat::zoo::resnets::resnet(18, 1.0);
    let soc = edgelat::device::soc_by_name("Snapdragon855").unwrap();
    let sc_cpu = one_large_core("Snapdragon855").expect("builtin soc");
    let sc_gpu = Scenario::gpu(&soc);

    bench("graph/build mobilenet_v2", 200, || {
        std::hint::black_box(edgelat::zoo::mobilenets::mobilenet_v2(1.0));
    });
    bench("graph/build full zoo (102 models)", 10, || {
        std::hint::black_box(edgelat::zoo::all_graphs());
    });
    bench("nas/sample one architecture", 500, || {
        std::hint::black_box(edgelat::nas::sample(7, 3));
    });
    bench("tflite/fuse mobilenet_v2", 200, || {
        std::hint::black_box(edgelat::tflite::fusion::fuse(&mv2));
    });
    bench("tflite/compile resnet18 (Mali)", 200, || {
        std::hint::black_box(compile(&r18, edgelat::tflite::GpuKind::Mali, CompileOptions::default()));
    });
    bench("features/extract all ops mobilenet_v2", 200, || {
        for n in &mv2.nodes {
            std::hint::black_box(edgelat::features::features(&mv2, n));
        }
    });
    let cpu_target = Target::Cpu {
        combo: edgelat::device::CoreCombo::new(vec![1, 3, 0]),
        rep: DataRep::Fp32,
    };
    bench("device/run mobilenet_v2 CPU 1L+3M", 200, || {
        std::hint::black_box(edgelat::device::run(&soc, &mv2, &cpu_target, 1, 0));
    });
    let gpu_target = Target::Gpu { options: CompileOptions::default() };
    bench("device/run mobilenet_v2 GPU", 200, || {
        std::hint::black_box(edgelat::device::run(&soc, &mv2, &gpu_target, 1, 0));
    });

    // Profiling throughput: the dominant cost of `reproduce --all`.
    let synth: Vec<_> = edgelat::nas::sample_dataset(3, 40).into_iter().map(|a| a.graph).collect();
    bench("profiler/profile_set 40 synth x5 runs CPU", 5, || {
        std::hint::black_box(profile_set(&sc_cpu, &synth, 3, 5));
    });
    bench("profiler/profile_set 40 synth x5 runs GPU", 5, || {
        std::hint::black_box(profile_set(&sc_gpu, &synth, 3, 5));
    });

    // Predictor training + inference on a realistic Conv2D bucket.
    let profiles = profile_set(&sc_cpu, &synth, 3, 5);
    let data = bucket_datasets(&profiles);
    let conv = &data["Conv2D"];
    println!("(Conv2D bucket: {} rows x {} features)", conv.x.len(), conv.x[0].len());
    for m in [Method::Lasso, Method::RandomForest, Method::Gbdt] {
        bench(&format!("predict/train {} on Conv2D bucket", m.name()), 3, || {
            std::hint::black_box(train(m, &conv.x, &conv.y, 1, None));
        });
    }
    let model = train(Method::Gbdt, &conv.x, &conv.y, 1, None);
    bench("predict/GBDT inference 1 op", 2000, || {
        std::hint::black_box(model.predict_raw(&conv.x[0]));
    });

    // End-to-end: train a scenario predictor and predict one model file.
    bench("framework/train ScenarioPredictor (GBDT)", 3, || {
        std::hint::black_box(edgelat::framework::ScenarioPredictor::train_from(
            &sc_cpu,
            &profiles,
            Method::Gbdt,
            edgelat::framework::DeductionMode::Full,
            1,
            None,
        ));
    });
    let pred = edgelat::framework::ScenarioPredictor::train_from(
        &sc_cpu,
        &profiles,
        Method::Gbdt,
        edgelat::framework::DeductionMode::Full,
        1,
        None,
    );
    bench("framework/predict mobilenet_v2 end-to-end", 500, || {
        std::hint::black_box(pred.predict(&mv2));
    });

    // Serving engine: load-once batch prediction (the train-once/serve
    // split; compare against `framework/train ScenarioPredictor` above,
    // which is what the old retrain-per-call `predict` paid per query).
    let bundle =
        edgelat::engine::PredictorBundle::from_predictor(&pred).expect("bundle from predictor");
    let engine = edgelat::engine::EngineBuilder::new()
        .bundle(bundle)
        .build()
        .expect("engine build");
    let serve: Vec<_> =
        edgelat::nas::sample_dataset(9, 100).into_iter().map(|a| a.graph).collect();
    bench("engine/predict_batch 100 NAs (loaded engine)", 10, || {
        let reqs: Vec<edgelat::engine::PredictRequest> = serve
            .iter()
            .map(|g| edgelat::engine::PredictRequest::new(g, sc_cpu.id.clone()))
            .collect();
        std::hint::black_box(engine.predict_batch(&reqs));
    });
    bench("engine/predict mobilenet_v2 (deduction memoized)", 2000, || {
        let req = edgelat::engine::PredictRequest::new(&mv2, sc_cpu.id.clone());
        std::hint::black_box(engine.predict(&req).expect("served"));
    });

    // Worker-pool substrate: raw fan-out overhead, and the scenario-sweep
    // pattern (profile K scenarios concurrently, each sequential inside)
    // used by the report prefetcher and `edgelat bench`.
    let nums: Vec<u64> = (0..10_000).collect();
    bench("exec_pool/map 10k trivial items", 50, || {
        std::hint::black_box(ExecPool::default().map(&nums, |_, &x| x.wrapping_mul(x)));
    });
    let sweep_sc: Vec<Scenario> = edgelat::scenario::all_scenarios().into_iter().take(6).collect();
    let sweep_g: Vec<_> =
        edgelat::nas::sample_dataset(5, 10).into_iter().map(|a| a.graph).collect();
    let seq = ExecPool::new(1);
    bench("sweep/profile 6 scenarios x 10 NAs sequential", 3, || {
        for sc in &sweep_sc {
            std::hint::black_box(profile_set_with(&seq, sc, &sweep_g, 5, 3));
        }
    });
    let pool = ExecPool::default();
    bench("sweep/profile 6 scenarios x 10 NAs pooled", 3, || {
        std::hint::black_box(
            pool.map(&sweep_sc, |_, sc| profile_set_with(&seq, sc, &sweep_g, 5, 3)),
        );
    });
    let stats = engine.cache_stats();
    println!(
        "(engine deduction memo: {} hits / {} misses / {} evictions across {} shards)",
        stats.hits,
        stats.misses,
        stats.evictions,
        engine.cache_shards()
    );
}
