//! Figure-regeneration benches (cargo bench --bench figures): one timed
//! entry per paper table/figure, run at smoke scale. This both validates
//! that every experiment in DESIGN.md §6 regenerates and tracks the
//! end-to-end cost of the reproduction harness (EXPERIMENTS.md §Perf).
//!
//! For paper-scale output run `edgelat reproduce --all --full`.

use edgelat::report::{all_ids, reproduce, ReportConfig, ReportCtx};
use std::time::Instant;

fn main() {
    println!("== figure/table regeneration benches (smoke scale) ==");
    let mut ctx = ReportCtx::new(ReportConfig::smoke());
    let mut total_rows = 0usize;
    let t_all = Instant::now();
    for id in all_ids() {
        let t0 = Instant::now();
        let tables = reproduce(id, &mut ctx);
        let rows: usize = tables.iter().map(|t| t.rows.len()).sum();
        total_rows += rows;
        println!(
            "fig/table {id:<4} {:>3} tables {:>4} rows   {:8.2} s",
            tables.len(),
            rows,
            t0.elapsed().as_secs_f64()
        );
        assert!(rows > 0, "figure {id} produced no rows");
    }
    println!(
        "\nALL {} figures/tables regenerated: {total_rows} rows in {:.1} s",
        all_ids().len(),
        t_all.elapsed().as_secs_f64()
    );
}
