#!/usr/bin/env python3
"""CI gate over the `edgelat bench` artifact (BENCH_pipeline.json).

Fails on a >2x slowdown of engine batch-predict relative to the
single-predict-per-item loop measured in the same process (i.e.
batch_predict_speedup < 0.5). The check is a ratio between two workloads
timed back-to-back on the same machine, not an absolute wall-clock
threshold, so it is robust to runner speed while still catching a
batch-path regression — e.g. the worker pool serializing on a global
lock, or per-request thread-spawn costs dwarfing the work.

Usage: bench_gate.py [BENCH_pipeline.json]
"""

import json
import math
import sys

# Batch-predict may be at most 2x slower than predicting the same
# requests one at a time; on multi-core runners it should be faster.
MIN_BATCH_SPEEDUP = 0.5


def fail(msg: str) -> int:
    print(f"FAIL: {msg}", file=sys.stderr)
    return 1


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_pipeline.json"
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return fail(f"cannot read {path}: {e}")

    if doc.get("format") != "edgelat.bench":
        return fail(f"{path} is not an edgelat bench artifact")
    if doc.get("version") != 1:
        return fail(f"unknown bench artifact version {doc.get('version')!r}")

    derived = doc.get("derived", {})
    speedup = derived.get("batch_predict_speedup")
    if not isinstance(speedup, (int, float)) or not math.isfinite(speedup) or speedup <= 0:
        return fail(f"missing/invalid batch_predict_speedup in {path}: {speedup!r}")

    if speedup < MIN_BATCH_SPEEDUP:
        return fail(
            f"predict_batch is {1.0 / speedup:.2f}x slower than the "
            f"single-predict loop (allowed: {1.0 / MIN_BATCH_SPEEDUP:.0f}x)"
        )

    sweep = derived.get("sweep_parallel_speedup")
    sweep_txt = f"{sweep:.2f}x" if isinstance(sweep, (int, float)) else repr(sweep)
    cache = derived.get("deduction_cache", {})
    print(
        f"OK: batch_predict_speedup={speedup:.2f}x "
        f"(threshold {MIN_BATCH_SPEEDUP}), "
        f"sweep_parallel_speedup={sweep_txt}, "
        f"cache hits/misses={cache.get('hits')}/{cache.get('misses')}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
