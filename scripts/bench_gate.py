#!/usr/bin/env python3
"""CI gate over the `edgelat bench` artifact (BENCH_pipeline.json).

Fails on:
- a >2x slowdown of engine batch-predict relative to the
  single-predict-per-item loop measured in the same process
  (batch_predict_speedup < 0.5), e.g. the worker pool serializing on a
  global lock or per-request thread-spawn costs dwarfing the work;
- a regressed parallel scenario sweep (sweep_parallel_speedup < 0.8):
  profiling K scenarios fanned out on the pool must not be meaningfully
  slower than doing them one at a time, whatever the runner's core count;
- a broken NAS-search stage (search.candidates_per_s <= 0, or a hit rate
  outside [0, 1]): the search loop must actually serve candidates through
  the engine, and its plan-cache accounting must be a real rate;
- an empty device registry (registry.scenarios <= 0): the registry-build
  stage parses the committed device specs and materializes every scenario —
  zero means the data-driven device universe failed to load;
- a broken serve-daemon stage (serve.requests_per_s <= 0, serve.mean_batch
  < 1, a non-finite or non-positive serve.p99_us/p50_us, or a hit rate
  outside [0, 1]): the daemon must answer real open-loop TCP traffic,
  micro-batching must actually coalesce (every flushed batch has >= 1
  item, so a mean below 1 means the accounting broke), and its tail
  latency must be a real measurement (the bench emits -1.0 in place of
  non-finite values so a silent NaN cannot slip through JSON);
- a broken fleet stage (fleet.socs <= 0, non-positive or non-finite
  fleet.scenarios_per_s / fleet.predictions_per_s, or
  fleet.vectorized_speedup < 1): the sampled spec universe must register
  and flow through the predictor, and the vectorized SoA kernels must not
  be slower than the scalar per-row reference on the same standardized
  matrices — below 1 the structure-of-arrays layout has regressed into
  pure overhead;
- a regressed binary bundle load (bundle_load.speedup < 1, or
  non-positive/non-finite bundle_load.json_ms / bundle_load.bin_ms): the
  zero-copy binary decode of a bundle must never lose to parsing the
  same models from JSON text in the same process;
- a broken compiled-LUT tier (lut.predictions_per_s <= 0,
  lut.lut_vs_soa_speedup < 1, or lut.max_rel_err outside
  [0, lut.bound]): the table probe must not be slower than the SoA model
  scan it replaces on identical in-grid plan rows, and the measured
  interpolation error must respect the compile-time bound the tables
  were verified against — above it, a table that should have been
  dropped is serving bad numbers;
- a broken few-shot transfer stage (missing derived.transfer,
  non-positive or non-finite transfer.adaptations_per_s, or the adapted
  predictor losing to the raw proxy baseline at the headline budget:
  adapted_rmspe > proxy_rmspe, or adapted_spearman < proxy_spearman when
  no degenerate correlations were skipped): onboarding a new device from
  K profiled graphs must produce a predictor at least as good as serving
  the source bundle unmodified — worse means the monotone map or the
  per-bucket recalibration regressed;
- a broken workload stage (missing derived.workload, zero contended
  scenarios, missing batch/contention axis coverage, non-positive
  predictions_per_s, or a non-finite/negative max_rmspe): the
  contention/batch cross-product must actually enumerate (builtin presets
  plus a sampled workload qualifying every isolated scenario), contended
  plan rows must flow through the predictor, and re-training under every
  workload regime must stay numerically sane — the bench emits -1.0 in
  place of a non-finite RMSPE, which this gate rejects.

Both checks are ratios between two workloads timed back-to-back on the
same machine, never absolute wall-clock thresholds, so they are robust to
runner speed while still catching structural regressions.

Usage: bench_gate.py [BENCH_pipeline.json]
"""

import json
import math
import sys

# Batch-predict may be at most 2x slower than predicting the same
# requests one at a time; on multi-core runners it should be faster.
MIN_BATCH_SPEEDUP = 0.5

# The pooled scenario sweep must stay within 25% of sequential even on a
# single-core runner (where the honest ratio is ~1.0); on multi-core
# runners it is well above 1. Below this, the sweep pool itself regressed.
MIN_SWEEP_SPEEDUP = 0.8

# The vectorized SoA kernels vs the scalar per-row reference on identical
# standardized matrices, single-threaded in one process. Unlike the pool
# ratios there is no runner-topology excuse here: breadth-first evaluation
# over a dense matrix must never lose to walking the same trees row by row.
MIN_VECTORIZED_SPEEDUP = 1.0

# Binary bundle decode vs JSON parse of the same models, cold from disk,
# back to back in one process. A sectioned memcpy-style decode losing to
# text float parsing means the format regressed into pure overhead.
MIN_BUNDLE_LOAD_SPEEDUP = 1.0

# The compiled LUT table probe vs the SoA model scan on identical in-grid
# plan rows. Below 1 the direct-lookup tier costs more than the model
# evaluation it is supposed to short-circuit.
MIN_LUT_SPEEDUP = 1.0


def fail(msg: str) -> int:
    print(f"FAIL: {msg}", file=sys.stderr)
    return 1


def ratio(derived: dict, key: str, path: str):
    value = derived.get(key)
    if not isinstance(value, (int, float)) or not math.isfinite(value) or value <= 0:
        return None, fail(f"missing/invalid {key} in {path}: {value!r}")
    return value, None


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_pipeline.json"
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return fail(f"cannot read {path}: {e}")

    if doc.get("format") != "edgelat.bench":
        return fail(f"{path} is not an edgelat bench artifact")
    if doc.get("version") != 1:
        return fail(f"unknown bench artifact version {doc.get('version')!r}")

    derived = doc.get("derived", {})
    speedup, err = ratio(derived, "batch_predict_speedup", path)
    if err is not None:
        return err
    if speedup < MIN_BATCH_SPEEDUP:
        return fail(
            f"predict_batch is {1.0 / speedup:.2f}x slower than the "
            f"single-predict loop (allowed: {1.0 / MIN_BATCH_SPEEDUP:.0f}x)"
        )

    sweep, err = ratio(derived, "sweep_parallel_speedup", path)
    if err is not None:
        return err
    if sweep < MIN_SWEEP_SPEEDUP:
        return fail(
            f"pooled scenario sweep is {1.0 / sweep:.2f}x slower than "
            f"sequential (allowed: {1.0 / MIN_SWEEP_SPEEDUP:.2f}x)"
        )

    registry = derived.get("registry")
    if not isinstance(registry, dict):
        return fail(f"missing derived.registry section in {path}")
    n_scenarios = registry.get("scenarios")
    if not isinstance(n_scenarios, (int, float)) or not n_scenarios > 0:
        return fail(
            f"registry-build stage reports no scenarios ({n_scenarios!r}); "
            "the device-spec registry failed to materialize"
        )
    n_socs = registry.get("socs")
    if not isinstance(n_socs, (int, float)) or not n_socs > 0:
        return fail(f"registry-build stage reports no SoCs ({n_socs!r})")

    search = derived.get("search")
    if not isinstance(search, dict):
        return fail(f"missing derived.search section in {path}")
    cps = search.get("candidates_per_s")
    if not isinstance(cps, (int, float)) or not math.isfinite(cps) or cps <= 0:
        return fail(f"search candidates_per_s must be > 0, got {cps!r}")
    hit_rate = search.get("plan_cache_hit_rate")
    if (
        not isinstance(hit_rate, (int, float))
        or not math.isfinite(hit_rate)
        or not 0.0 <= hit_rate <= 1.0
    ):
        return fail(f"search plan_cache_hit_rate must be in [0, 1], got {hit_rate!r}")

    serve = derived.get("serve")
    if not isinstance(serve, dict):
        return fail(f"missing derived.serve section in {path}")
    rps = serve.get("requests_per_s")
    if not isinstance(rps, (int, float)) or not math.isfinite(rps) or rps <= 0:
        return fail(f"serve requests_per_s must be > 0, got {rps!r}")
    mean_batch = serve.get("mean_batch")
    if (
        not isinstance(mean_batch, (int, float))
        or not math.isfinite(mean_batch)
        or mean_batch < 1.0
    ):
        return fail(
            f"serve mean_batch must be >= 1 (every flushed batch holds at "
            f"least one request), got {mean_batch!r}"
        )
    for pct in ("p50_us", "p99_us"):
        v = serve.get(pct)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            return fail(f"serve {pct} must be a finite positive latency, got {v!r}")
    serve_hit = serve.get("plan_cache_hit_rate")
    if (
        not isinstance(serve_hit, (int, float))
        or not math.isfinite(serve_hit)
        or not 0.0 <= serve_hit <= 1.0
    ):
        return fail(f"serve plan_cache_hit_rate must be in [0, 1], got {serve_hit!r}")

    fleet = derived.get("fleet")
    if not isinstance(fleet, dict):
        return fail(f"missing derived.fleet section in {path}")
    fleet_socs = fleet.get("socs")
    if not isinstance(fleet_socs, (int, float)) or not fleet_socs > 0:
        return fail(f"fleet stage reports no sampled SoCs ({fleet_socs!r})")
    for key in ("scenarios_per_s", "predictions_per_s"):
        v = fleet.get(key)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            return fail(f"fleet {key} must be a finite positive rate, got {v!r}")
    vec_speedup = fleet.get("vectorized_speedup")
    if (
        not isinstance(vec_speedup, (int, float))
        or not math.isfinite(vec_speedup)
        or vec_speedup <= 0
    ):
        return fail(f"fleet vectorized_speedup must be > 0, got {vec_speedup!r}")
    if vec_speedup < MIN_VECTORIZED_SPEEDUP:
        return fail(
            f"vectorized kernels are {1.0 / vec_speedup:.2f}x slower than the "
            f"scalar reference (required: >= {MIN_VECTORIZED_SPEEDUP:.1f}x)"
        )

    bundle_load = derived.get("bundle_load")
    if not isinstance(bundle_load, dict):
        return fail(f"missing derived.bundle_load section in {path}")
    for key in ("json_ms", "bin_ms"):
        v = bundle_load.get(key)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            return fail(f"bundle_load {key} must be a finite positive time, got {v!r}")
    bin_speedup = bundle_load.get("speedup")
    if (
        not isinstance(bin_speedup, (int, float))
        or not math.isfinite(bin_speedup)
        or bin_speedup <= 0
    ):
        return fail(f"bundle_load speedup must be > 0, got {bin_speedup!r}")
    if bin_speedup < MIN_BUNDLE_LOAD_SPEEDUP:
        return fail(
            f"binary bundle load is {1.0 / bin_speedup:.2f}x slower than the "
            f"JSON parse (required: >= {MIN_BUNDLE_LOAD_SPEEDUP:.1f}x)"
        )

    lut = derived.get("lut")
    if not isinstance(lut, dict):
        return fail(f"missing derived.lut section in {path}")
    lut_pps = lut.get("predictions_per_s")
    if not isinstance(lut_pps, (int, float)) or not math.isfinite(lut_pps) or lut_pps <= 0:
        return fail(f"lut predictions_per_s must be > 0, got {lut_pps!r}")
    lut_speedup = lut.get("lut_vs_soa_speedup")
    if (
        not isinstance(lut_speedup, (int, float))
        or not math.isfinite(lut_speedup)
        or lut_speedup <= 0
    ):
        return fail(f"lut lut_vs_soa_speedup must be > 0, got {lut_speedup!r}")
    if lut_speedup < MIN_LUT_SPEEDUP:
        return fail(
            f"the LUT table probe is {1.0 / lut_speedup:.2f}x slower than the "
            f"SoA model scan (required: >= {MIN_LUT_SPEEDUP:.1f}x)"
        )
    lut_bound = lut.get("bound")
    if not isinstance(lut_bound, (int, float)) or not math.isfinite(lut_bound) or lut_bound <= 0:
        return fail(f"lut bound must be a finite positive error bound, got {lut_bound!r}")
    lut_err = lut.get("max_rel_err")
    if (
        not isinstance(lut_err, (int, float))
        or not math.isfinite(lut_err)
        or not 0.0 <= lut_err <= lut_bound
    ):
        return fail(
            f"lut max_rel_err must be in [0, {lut_bound!r}] (the bound the "
            f"tables were verified against), got {lut_err!r}"
        )

    transfer = derived.get("transfer")
    if not isinstance(transfer, dict):
        return fail(f"missing derived.transfer section in {path}")
    aps = transfer.get("adaptations_per_s")
    if not isinstance(aps, (int, float)) or not math.isfinite(aps) or aps <= 0:
        return fail(f"transfer adaptations_per_s must be > 0, got {aps!r}")
    for key in ("proxy_rmspe", "adapted_rmspe"):
        v = transfer.get(key)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            return fail(f"transfer {key} must be a finite positive error, got {v!r}")
    t_proxy_rmspe = transfer["proxy_rmspe"]
    t_adapted_rmspe = transfer["adapted_rmspe"]
    if t_adapted_rmspe > t_proxy_rmspe:
        return fail(
            f"few-shot adapted RMSPE {t_adapted_rmspe:.4f} is worse than the "
            f"raw proxy baseline {t_proxy_rmspe:.4f} at the headline budget"
        )
    degenerate = transfer.get("degenerate_pairs")
    if not isinstance(degenerate, (int, float)) or not math.isfinite(degenerate):
        return fail(f"transfer degenerate_pairs must be a finite count, got {degenerate!r}")
    if degenerate == 0:
        for key in ("proxy_spearman", "adapted_spearman"):
            v = transfer.get(key)
            if not isinstance(v, (int, float)) or not math.isfinite(v):
                return fail(f"transfer {key} must be finite when no pair was degenerate, got {v!r}")
        if transfer["adapted_spearman"] < transfer["proxy_spearman"]:
            return fail(
                f"few-shot adapted Spearman {transfer['adapted_spearman']:.4f} ranks "
                f"worse than the proxy baseline {transfer['proxy_spearman']:.4f}"
            )

    workload = derived.get("workload")
    if not isinstance(workload, dict):
        return fail(f"missing derived.workload section in {path}")
    wl_scenarios = workload.get("scenarios")
    if not isinstance(wl_scenarios, (int, float)) or not wl_scenarios > 0:
        return fail(f"workload stage reports no scenarios ({wl_scenarios!r})")
    wl_contended = workload.get("contended_scenarios")
    if not isinstance(wl_contended, (int, float)) or not wl_contended > 0:
        return fail(
            f"workload stage reports no contended scenarios ({wl_contended!r}); "
            "the contention/batch cross-product failed to enumerate"
        )
    for key in ("batch_axes", "contention_axes"):
        v = workload.get(key)
        if not isinstance(v, (int, float)) or not v > 0:
            return fail(f"workload {key} must be > 0, got {v!r}")
    wl_pps = workload.get("predictions_per_s")
    if not isinstance(wl_pps, (int, float)) or not math.isfinite(wl_pps) or wl_pps <= 0:
        return fail(f"workload predictions_per_s must be > 0, got {wl_pps!r}")
    wl_rmspe = workload.get("max_rmspe")
    if not isinstance(wl_rmspe, (int, float)) or not math.isfinite(wl_rmspe) or wl_rmspe < 0:
        return fail(
            f"workload max_rmspe must be a finite non-negative error, got {wl_rmspe!r}; "
            "the contended re-train sweep went numerically bad"
        )

    lowering = derived.get("lowering", {})
    graphs_per_s = lowering.get("graphs_per_s")
    lowering_txt = (
        f"{graphs_per_s:.0f} graphs/s"
        if isinstance(graphs_per_s, (int, float))
        else repr(graphs_per_s)
    )
    cache = derived.get("plan_cache", {})
    print(
        f"OK: registry={n_socs:.0f} SoCs/{n_scenarios:.0f} scenarios, "
        f"batch_predict_speedup={speedup:.2f}x "
        f"(threshold {MIN_BATCH_SPEEDUP}), "
        f"sweep_parallel_speedup={sweep:.2f}x "
        f"(threshold {MIN_SWEEP_SPEEDUP}), "
        f"lowering={lowering_txt}, "
        f"fleet={fleet_socs:.0f} SoCs "
        f"({fleet.get('predictions_per_s'):.0f} predictions/s, "
        f"vectorized_speedup={vec_speedup:.2f}x, "
        f"threshold {MIN_VECTORIZED_SPEEDUP}), "
        f"bundle_load={bin_speedup:.2f}x vs JSON "
        f"(threshold {MIN_BUNDLE_LOAD_SPEEDUP}), "
        f"lut={lut_speedup:.2f}x vs SoA "
        f"({lut_pps:.0f} predictions/s, "
        f"max_rel_err {lut_err:.4f} <= bound {lut_bound}), "
        f"transfer={aps:.1f} adaptations/s "
        f"(rmspe {t_adapted_rmspe:.3f} vs proxy {t_proxy_rmspe:.3f}), "
        f"workload={wl_contended:.0f} contended scenarios "
        f"({wl_pps:.0f} predictions/s, max_rmspe {wl_rmspe:.3f}), "
        f"search={cps:.0f} candidates/s "
        f"(plan-cache hit rate {hit_rate:.2f}), "
        f"serve={rps:.0f} req/s "
        f"(p50 {serve.get('p50_us'):.0f} us, p99 {serve.get('p99_us'):.0f} us, "
        f"mean batch {mean_batch:.2f}, hit rate {serve_hit:.2f}), "
        f"plan cache hits/misses={cache.get('hits')}/{cache.get('misses')}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
