#!/usr/bin/env python3
"""Generate the binary golden bundle fixture from the JSON golden.

Reads rust/tests/data/golden_bundle.json (the pinned v2 JSON golden) and
the committed Snapdragon855 device spec, and writes
rust/tests/data/golden_bundle.bin: the same bundle in the binary format
of rust/src/engine/binfmt.rs, byte-for-byte what
`PredictorBundle::to_bin_bytes()` emits for the loaded golden. The Rust
test `binfmt_roundtrip::golden_bin_fixture_is_byte_stable` decodes the
committed bytes, re-encodes, and asserts equality — so this script and
the Rust encoder pin each other.

The only subtle part is the embedded scenario descriptor, which is
*text*: compact JSON with BTreeMap-sorted keys and Rust's f64 Display
(integral values < 1e15 print as integers, everything else shortest
repr, never scientific notation). Python's repr() produces the same
shortest decimal for the magnitudes in the committed specs; the emitter
asserts no exponent sneaks in.

Usage: make_golden_bin.py   (run from the repo root; rewrites the .bin)
"""

import json
import os
import struct
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_JSON = os.path.join(ROOT, "rust", "tests", "data", "golden_bundle.json")
SPEC_JSON = os.path.join(ROOT, "rust", "src", "device", "specs", "snapdragon855.json")
OUT = os.path.join(ROOT, "rust", "tests", "data", "golden_bundle.bin")

MAGIC = b"EDGELATB"
VERSION = 1
HEADER_LEN = 104

# plan::BucketInterner::builtin() — OpType::all() names + the two
# kernel-selection-only buckets, in stable id order.
INTERNER = [
    "Conv2D",
    "GroupedConv2D",
    "DepthwiseConv2D",
    "FullyConnected",
    "Pooling",
    "Mean",
    "Concat/Split",
    "Pad",
    "ElementWise",
    "Activation",
    "Softmax",
    "Reshape",
    "Winograd",
    "NaiveGroupedConv2D",
]

METHOD_CODES = {"Lasso": 0, "RF": 1, "GBDT": 2}
MODE_CODES = {"full": 0, "nofusion": 1, "noselection": 2}

# soc_to_json field sets (device/spec.rs) — the descriptor embeds exactly
# these, not the spec file's format/version/combos envelope.
SOC_FIELDS = [
    "name",
    "platform",
    "clusters",
    "gpu",
    "mem_gbps",
    "cpu_op_overhead_us",
    "cpu_overhead_ms",
    "hetero_sync_mult",
    "quant_ew_penalty",
    "noise_base",
    "noise_per_small_core",
    "noise_per_extra_core",
]
CLUSTER_FIELDS = ["kind", "name", "count", "ghz", "flops_per_cycle", "int8_speedup", "stream_gbps"]
GPU_FIELDS = [
    "kind",
    "name",
    "gflops",
    "mem_gbps",
    "dispatch_us",
    "overhead_ms",
    "overhead_sigma",
    "run_sigma",
]


def emit_json(v) -> str:
    """Mirror util::Json::write: compact, keys BTreeMap-sorted, Rust f64
    Display for numbers."""
    if v is True:
        return "true"
    if v is False:
        return "false"
    if isinstance(v, (int, float)):
        f = float(v)
        if f == int(f) and abs(f) < 1e15:
            return str(int(f))
        r = repr(f)
        assert "e" not in r and "E" not in r, f"exponent form {r} diverges from Rust Display"
        return r
    if isinstance(v, str):
        out = ['"']
        for c in v:
            if c == '"':
                out.append('\\"')
            elif c == "\\":
                out.append("\\\\")
            elif c == "\n":
                out.append("\\n")
            elif c == "\t":
                out.append("\\t")
            elif c == "\r":
                out.append("\\r")
            elif ord(c) < 0x20:
                out.append(f"\\u{ord(c):04x}")
            else:
                out.append(c)
        out.append('"')
        return "".join(out)
    if isinstance(v, list):
        return "[" + ",".join(emit_json(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(
            f"{emit_json(k)}:{emit_json(v[k])}" for k in sorted(v)
        ) + "}"
    raise TypeError(f"unexpected value {v!r}")


class Writer:
    def __init__(self):
        self.buf = bytearray()

    def u32(self, v):
        self.buf += struct.pack("<I", v)

    def u64(self, v):
        self.buf += struct.pack("<Q", v)

    def f64(self, v):
        self.buf += struct.pack("<d", v)

    def bytes(self, b):
        self.buf += b

    def pad8(self):
        while len(self.buf) % 8 != 0:
            self.buf.append(0)


def align8(n: int) -> int:
    return (n + 7) & ~7


def descriptor(spec: dict, scenario_id: str) -> bytes:
    device = {k: spec[k] for k in SOC_FIELDS}
    device["clusters"] = [{f: c[f] for f in CLUSTER_FIELDS} for c in spec["clusters"]]
    device["gpu"] = {f: spec["gpu"][f] for f in GPU_FIELDS}

    # Scenario id: "<soc>/cpu/<combo-label>/<rep>"; resolve the combo
    # label (e.g. "1L", "1L+3M") against the spec's combos the way
    # CoreCombo::label does.
    parts = scenario_id.split("/")
    assert len(parts) == 4 and parts[1] == "cpu", f"CPU golden expected, got {scenario_id}"
    letters = {"large": "L", "medium": "M", "small": "S"}

    def label(counts):
        return "+".join(
            f"{c}{letters[spec['clusters'][i]['kind']]}" for i, c in enumerate(counts) if c > 0
        )

    counts = next(c for c in spec["combos"] if label(c) == parts[2])
    target = {"counts": counts, "kind": "cpu", "rep": parts[3]}
    doc = {"device": device, "scenario": scenario_id, "target": target}
    return emit_json(doc).encode()


def encode_model(w: Writer, name_idx: int, bucket: dict):
    std = bucket["standardizer"]
    model = bucket["model"]
    dim = len(std["mean"])
    assert dim == bucket["dim"] == len(std["std"]) == len(model["weights"])
    assert model["kind"] == "lasso", "golden is a Lasso bundle"
    w.u32(name_idx)
    w.u32(METHOD_CODES["Lasso"])
    w.u32(dim)
    w.u32(dim)  # aux == dim for lasso
    w.f64(bucket["floor"])
    for v in std["mean"]:
        w.f64(v)
    for v in std["std"]:
        w.f64(v)
    w.f64(model["intercept"])
    w.f64(model["alpha"])
    for v in model["weights"]:
        w.f64(v)


def main() -> int:
    with open(GOLDEN_JSON) as f:
        golden = json.load(f)
    with open(SPEC_JSON) as f:
        spec = json.load(f)
    assert golden["format"] == "edgelat.predictor_bundle"
    assert golden["method"] == "Lasso" and golden["mode"] == "full"

    strings = Writer()
    for n in INTERNER:
        strings.u32(len(n.encode()))
    strings.pad8()
    for n in INTERNER:
        strings.bytes(n.encode())

    desc = descriptor(spec, golden["scenario"])

    models = Writer()
    for name in sorted(golden["buckets"]):  # BTreeMap order
        encode_model(models, INTERNER.index(name), golden["buckets"][name])

    strings_off = HEADER_LEN
    desc_off = align8(strings_off + len(strings.buf))
    models_off = align8(desc_off + len(desc))
    total_len = align8(models_off + len(models.buf))

    w = Writer()
    w.bytes(MAGIC)
    w.u32(VERSION)
    w.u32(METHOD_CODES[golden["method"]])
    w.u32(MODE_CODES[golden["mode"]])
    w.u32(len(INTERNER))
    w.u32(len(golden["buckets"]))
    w.u32(0)  # reserved
    w.f64(golden["t_overhead_ms"])
    w.f64(golden["fallback_ms"])
    w.u64(strings_off)
    w.u64(len(strings.buf))
    w.u64(desc_off)
    w.u64(len(desc))
    w.u64(models_off)
    w.u64(len(models.buf))
    w.u64(total_len)
    assert len(w.buf) == HEADER_LEN
    w.bytes(strings.buf)
    w.pad8()
    w.bytes(desc)
    w.pad8()
    w.bytes(models.buf)
    w.pad8()
    assert len(w.buf) == total_len

    with open(OUT, "wb") as f:
        f.write(w.buf)
    print(f"wrote {OUT} ({total_len} bytes, {len(golden['buckets'])} bucket models)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
