#!/usr/bin/env python3
"""Fixture smoke test for bench_gate.py — run by CI before the real gate.

Builds synthetic BENCH_pipeline.json documents in a temp dir and asserts
the gate's verdict on each: a healthy artifact passes, and each class of
regression the gate documents (slow batch predict, missing fleet section,
sub-1x vectorized speedup, dead throughput, a binary bundle load losing
to JSON, a LUT tier slower than the SoA scan or serving outside its
verified error bound, a few-shot transfer stage that is missing, dead, or
adapting predictors worse than the raw proxy baseline, a workload stage
that is missing, enumerates no contended scenarios, loses an axis, or
reports a non-finite contended RMSPE) fails with exit code 1. This
keeps the gate itself honest: a refactor that silently stops checking a
section shows up here, not as a green CI on a broken bench.

Usage: test_bench_gate.py
"""

import copy
import json
import os
import subprocess
import sys
import tempfile

GATE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_gate.py")

HEALTHY = {
    "format": "edgelat.bench",
    "version": 1,
    "profile": "quick",
    "threads": 4,
    "benches": [],
    "derived": {
        "registry": {"scenarios": 72, "socs": 4, "builds_per_s": 500.0},
        "batch_predict_speedup": 2.4,
        "plan_predict_speedup": 3.1,
        "sweep_parallel_speedup": 1.9,
        "fleet": {
            "socs": 100,
            "scenarios": 700,
            "graphs": 2,
            "unit_rows": 40000,
            "scenarios_per_s": 900.0,
            "predictions_per_s": 2.5e6,
            "vectorized_speedup": 1.8,
        },
        "bundle_load": {"json_ms": 4.2, "bin_ms": 0.6, "speedup": 7.0},
        "lut": {
            "tables": 9,
            "table_entries": 24000,
            "predictions_per_s": 5.0e6,
            "lut_vs_soa_speedup": 2.2,
            "max_rel_err": 0.011,
            "bound": 0.05,
        },
        "workload": {
            "scenarios": 360,
            "contended_scenarios": 288,
            "workloads": 4,
            "batch_axes": 3,
            "contention_axes": 3,
            "unit_rows": 9000,
            "predictions_per_s": 1.0e6,
            "max_rmspe": 0.3,
            "eval_rows": 8,
            "eval_contended": 6,
        },
        "transfer": {
            "budget": 10,
            "adaptations_per_s": 40.0,
            "proxy_rmspe": 0.8,
            "adapted_rmspe": 0.2,
            "proxy_spearman": 0.9,
            "adapted_spearman": 0.95,
            "dropped_rows": 0,
            "degenerate_pairs": 0,
            "map_knots": 6,
        },
        "lowering": {
            "graphs_per_s": 4000.0,
            "units_per_s": 260000.0,
            "units_per_graph": 65.0,
        },
        "search": {
            "candidates_per_s": 800.0,
            "evaluated": 40,
            "plan_cache_hit_rate": 0.4,
        },
        "serve": {
            "requests_per_s": 500.0,
            "p50_us": 900.0,
            "p99_us": 4000.0,
            "mean_batch": 2.5,
            "plan_cache_hit_rate": 0.6,
            "sent": 200,
            "ok": 200,
            "errors": 0,
        },
        "plan_cache": {"hits": 100, "misses": 20, "evictions": 0, "shards": 8},
    },
}


def run_gate(doc: dict, tmp: str, name: str) -> int:
    path = os.path.join(tmp, name)
    with open(path, "w") as f:
        json.dump(doc, f)
    proc = subprocess.run(
        [sys.executable, GATE, path],
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )
    print(f"  [{name}] rc={proc.returncode}: {proc.stdout.strip().splitlines()[-1]}")
    return proc.returncode


def mutate(edit):
    doc = copy.deepcopy(HEALTHY)
    edit(doc)
    return doc


def main() -> int:
    cases = [
        ("healthy artifact passes", HEALTHY, 0),
        (
            "slow batch predict fails",
            mutate(lambda d: d["derived"].__setitem__("batch_predict_speedup", 0.3)),
            1,
        ),
        (
            "missing fleet section fails",
            mutate(lambda d: d["derived"].pop("fleet")),
            1,
        ),
        (
            "sub-1x vectorized speedup fails",
            mutate(lambda d: d["derived"]["fleet"].__setitem__("vectorized_speedup", 0.8)),
            1,
        ),
        (
            "non-finite vectorized speedup fails",
            mutate(lambda d: d["derived"]["fleet"].__setitem__("vectorized_speedup", -1.0)),
            1,
        ),
        (
            "dead fleet throughput fails",
            mutate(lambda d: d["derived"]["fleet"].__setitem__("predictions_per_s", 0.0)),
            1,
        ),
        (
            "no sampled SoCs fails",
            mutate(lambda d: d["derived"]["fleet"].__setitem__("socs", 0)),
            1,
        ),
        (
            "empty registry fails",
            mutate(lambda d: d["derived"]["registry"].__setitem__("scenarios", 0)),
            1,
        ),
        (
            "dead serve daemon fails",
            mutate(lambda d: d["derived"]["serve"].__setitem__("requests_per_s", -1.0)),
            1,
        ),
        (
            "binary bundle load slower than JSON fails",
            mutate(lambda d: d["derived"]["bundle_load"].__setitem__("speedup", 0.7)),
            1,
        ),
        (
            "missing bundle_load section fails",
            mutate(lambda d: d["derived"].pop("bundle_load")),
            1,
        ),
        (
            "non-positive bundle load time fails",
            mutate(lambda d: d["derived"]["bundle_load"].__setitem__("bin_ms", 0.0)),
            1,
        ),
        (
            "sub-1x LUT speedup fails",
            mutate(lambda d: d["derived"]["lut"].__setitem__("lut_vs_soa_speedup", 0.9)),
            1,
        ),
        (
            "LUT error above its verified bound fails",
            mutate(lambda d: d["derived"]["lut"].__setitem__("max_rel_err", 0.08)),
            1,
        ),
        (
            "non-finite LUT error fails",
            mutate(lambda d: d["derived"]["lut"].__setitem__("max_rel_err", -1.0)),
            1,
        ),
        (
            "missing lut section fails",
            mutate(lambda d: d["derived"].pop("lut")),
            1,
        ),
        (
            "dead LUT throughput fails",
            mutate(lambda d: d["derived"]["lut"].__setitem__("predictions_per_s", 0.0)),
            1,
        ),
        (
            "missing transfer section fails",
            mutate(lambda d: d["derived"].pop("transfer")),
            1,
        ),
        (
            "dead transfer adaptation rate fails",
            mutate(lambda d: d["derived"]["transfer"].__setitem__("adaptations_per_s", 0.0)),
            1,
        ),
        (
            "non-finite adapted RMSPE fails",
            mutate(lambda d: d["derived"]["transfer"].__setitem__("adapted_rmspe", -1.0)),
            1,
        ),
        (
            "adapted worse than proxy on RMSPE fails",
            mutate(lambda d: d["derived"]["transfer"].__setitem__("adapted_rmspe", 0.9)),
            1,
        ),
        (
            "adapted ranking worse than proxy fails",
            mutate(lambda d: d["derived"]["transfer"].__setitem__("adapted_spearman", 0.5)),
            1,
        ),
        (
            "missing workload section fails",
            mutate(lambda d: d["derived"].pop("workload")),
            1,
        ),
        (
            "zero contended scenarios fails",
            mutate(lambda d: d["derived"]["workload"].__setitem__("contended_scenarios", 0)),
            1,
        ),
        (
            "non-finite workload RMSPE fails",
            mutate(lambda d: d["derived"]["workload"].__setitem__("max_rmspe", -1.0)),
            1,
        ),
        (
            "missing contention axis coverage fails",
            mutate(lambda d: d["derived"]["workload"].__setitem__("contention_axes", 0)),
            1,
        ),
        (
            "dead contended predict throughput fails",
            mutate(lambda d: d["derived"]["workload"].__setitem__("predictions_per_s", 0.0)),
            1,
        ),
        (
            "degenerate spearman pairs skip the rank check",
            mutate(
                lambda d: d["derived"]["transfer"].update(
                    {"degenerate_pairs": 1, "proxy_spearman": -1.0, "adapted_spearman": -1.0}
                )
            ),
            0,
        ),
    ]
    failures = 0
    with tempfile.TemporaryDirectory() as tmp:
        for i, (label, doc, want) in enumerate(cases):
            print(f"case: {label}")
            got = run_gate(doc, tmp, f"fixture_{i}.json")
            if got != want:
                print(f"  MISMATCH: expected rc={want}, got rc={got}", file=sys.stderr)
                failures += 1
    if failures:
        print(f"FAIL: {failures} gate fixture case(s) misbehaved", file=sys.stderr)
        return 1
    print(f"OK: {len(cases)} gate fixture cases behaved as expected")
    return 0


if __name__ == "__main__":
    sys.exit(main())
