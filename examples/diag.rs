use edgelat::features::Standardizer;
use edgelat::predict::lasso::Lasso;
use edgelat::predict::Regressor;
use edgelat::profiler::{bucket_datasets, profile_set};
use edgelat::scenario::one_large_core;

// Calibration diagnostic: per-bucket Lasso fits with per-decade MAPE/bias.
// Used while tuning the device cost model (EXPERIMENTS.md §Perf); kept as a
// developer tool: `cargo run --release --example diag`.

fn main() {
    let graphs: Vec<_> = edgelat::nas::sample_dataset(2022, 120).into_iter().map(|a| a.graph).collect();
    let sc = one_large_core("Snapdragon855").expect("builtin soc");
    let profiles = profile_set(&sc, &graphs, 2022, 5);
    let data = bucket_datasets(&profiles);
    for bucket in ["Conv2D", "FullyConnected", "DepthwiseConv2D"] {
        let d = &data[bucket];
        let s = Standardizer::fit(&d.x);
        let xs = s.transform_all(&d.x);
        let l = Lasso::fit_cv(&xs, &d.y, 1);
        println!("== {bucket}: n={} alpha={} intercept={:.4}", d.y.len(), l.alpha, l.intercept);
        println!("   weights: {:?}", l.weights.iter().map(|w| (w * 1000.0).round() / 1000.0).collect::<Vec<_>>());
        for (lo, hi) in [(0.0, 0.01), (0.01, 0.1), (0.1, 1.0), (1.0, 10.0), (10.0, 1e9)] {
            let sel: Vec<(f64, f64)> = xs.iter().zip(&d.y).filter(|(_, &y)| y >= lo && y < hi)
                .map(|(x, &y)| (l.predict_one(x).max(1e-9), y)).collect();
            if sel.len() < 3 { continue; }
            let m = sel.iter().map(|(p, a)| ((p - a) / a).abs()).sum::<f64>() / sel.len() as f64;
            let bias = sel.iter().map(|(p, a)| (p - a) / a).sum::<f64>() / sel.len() as f64;
            println!("   y [{lo:>5}..{hi:<5}) n={:<5} MAPE {:6.1}%  bias {:+6.1}%", sel.len(), m * 100.0, bias * 100.0);
        }
    }
}
