//! End-to-end three-layer validation driver (the EXPERIMENTS.md §E2E run).
//!
//! Exercises the full stack on a real small workload:
//!   L1 Pallas fused_dense kernels -> L2 JAX MLP train step (AOT HLO text)
//!   -> L3 rust: profiles 80 synthetic NAs on the simulated Pixel 4, trains
//!   the MLP latency predictor for the Conv2D bucket BY EXECUTING THE AOT
//!   TRAIN STEP THROUGH PJRT, logs the loss curve, and reports test MAPE
//!   against GBDT on the same data.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example end_to_end_mlp

use edgelat::features::Standardizer;
use edgelat::predict::mlp::{MlpContext, MlpModel};
use edgelat::predict::{train, Method, Regressor};
use edgelat::profiler::{bucket_datasets, profile_set};
use edgelat::runtime::Runtime;
use edgelat::scenario::one_large_core;
use edgelat::util::mape;

fn main() {
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let ctx = MlpContext::load(&dir).expect("loading artifacts");
    println!(
        "loaded {} AOT MLP variants: {:?}",
        ctx.variants.len(),
        ctx.variants.iter().map(|v| v.name.as_str()).collect::<Vec<_>>()
    );

    // L3: collect a real training workload from the simulated device.
    let seed = 2022;
    let sc = one_large_core("Snapdragon855").expect("builtin soc");
    let graphs: Vec<_> =
        edgelat::nas::sample_dataset(seed, 80).into_iter().map(|a| a.graph).collect();
    println!("profiling {} synthetic NAs on {} ...", graphs.len(), sc.id);
    let profiles = profile_set(&sc, &graphs, seed, 5);
    let data = bucket_datasets(&profiles);
    let conv = &data["Conv2D"];
    println!("Conv2D bucket: {} samples x {} features", conv.x.len(), conv.x[0].len());

    let n_test = conv.x.len() / 5;
    let (test_x, train_x) = conv.x.split_at(n_test);
    let (test_y, train_y) = conv.y.split_at(n_test);

    // L2+L1 via PJRT: train the MLP (Adam steps executed as AOT HLO).
    let t0 = std::time::Instant::now();
    let std = Standardizer::fit(train_x);
    let xs = std.transform_all(train_x);
    let model = MlpModel::fit(&ctx, &xs, train_y, seed);
    println!("MLP trained through PJRT in {:.1}s", t0.elapsed().as_secs_f64());
    let xt = std.transform_all(test_x);
    let pred: Vec<f64> = model.predict(&xt).iter().map(|&p| p.max(1e-9)).collect();
    let mlp_mape = mape(&pred, test_y);

    // Baseline: native GBDT on the identical split.
    let gb = train(Method::Gbdt, train_x, train_y, seed, None);
    let gb_pred: Vec<f64> = test_x.iter().map(|v| gb.predict_raw(v)).collect();
    let gb_mape = mape(&gb_pred, test_y);

    println!("\nConv2D latency prediction on {} held-out ops:", test_x.len());
    println!("  MLP  (AOT JAX+Pallas via PJRT): MAPE {:.2}%", mlp_mape * 100.0);
    println!("  GBDT (native rust)            : MAPE {:.2}%", gb_mape * 100.0);
    assert!(mlp_mape < 0.5, "MLP should be broadly correct (got {mlp_mape})");
    println!("\nOK: all three layers compose.");
}
