//! Latency-constrained NAS — the paper's motivating application
//! (Section 1): search a NAS space for the highest-"accuracy" architecture
//! under a latency budget, using the prediction framework instead of
//! device-in-the-loop measurement, then validate the winners on the device.
//!
//! Accuracy is proxied by log-FLOPs (a standing NAS heuristic); the point of
//! the example is the *latency* side: candidates are scored by a loaded
//! `LatencyEngine` at NAS-search rate — train once, `predict_batch` many —
//! ~1000x cheaper than profiling each candidate.
//!
//! Run: `cargo run --release --example nas_latency_constrained`

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::DeductionMode;
use edgelat::predict::Method;
use edgelat::profiler::{profile, profile_set};
use edgelat::scenario::Scenario;
use std::time::Instant;

fn main() {
    let seed = 7;
    let budget_ms = 60.0;
    let soc = edgelat::device::soc_by_name("Exynos9820").unwrap();
    let sc = Scenario::cpu(&soc, vec![1, 0, 0], edgelat::device::DataRep::Fp32)
        .expect("1L is a valid Exynos9820 combo");
    println!("NAS under a {budget_ms} ms budget on {}", sc.id);

    // One-time profiling + predictor training (30 architectures — the
    // paper's minimal-data regime, Section 5.5), frozen into a bundle and
    // loaded into the serving engine.
    let train: Vec<_> =
        edgelat::nas::sample_dataset(seed, 30).into_iter().map(|a| a.graph).collect();
    let profiles = profile_set(&sc, &train, seed, 5);
    let bundle = PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, seed)
        .expect("training bundle");
    let engine = EngineBuilder::new().bundle(bundle).build().expect("building engine");

    // Search: score 400 candidates by predicted latency, batched across
    // threads on the loaded engine.
    let t0 = Instant::now();
    let candidates: Vec<edgelat::graph::Graph> = edgelat::nas::sample_dataset(seed ^ 0xbeef, 400)
        .into_iter()
        .map(|a| a.graph)
        .collect();
    let reqs: Vec<PredictRequest> =
        candidates.iter().map(|g| PredictRequest::new(g, sc.id.clone())).collect();
    let responses = engine.predict_batch(&reqs);
    let mut feasible: Vec<(f64, f64, String, edgelat::graph::Graph)> = Vec::new();
    for (g, resp) in candidates.iter().zip(responses) {
        let lat = resp.expect("served prediction").e2e_ms;
        if lat <= budget_ms {
            let acc_proxy = (g.flops() as f64).ln();
            feasible.push((acc_proxy, lat, g.name.clone(), g.clone()));
        }
    }
    feasible.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    println!(
        "scored {} candidates in {:.2}s (predict_batch on the loaded engine); {} within budget",
        candidates.len(),
        t0.elapsed().as_secs_f64(),
        feasible.len()
    );

    // Validate the top-5 on the device (simulated measurement).
    println!("\n{:<14} {:>12} {:>12} {:>8}", "candidate", "predicted", "measured", "err%");
    for (acc, lat, name, g) in feasible.iter().take(5) {
        let measured = profile(&sc, g, seed, 10).end_to_end_ms;
        println!(
            "{name:<14} {lat:>10.2}ms {measured:>10.2}ms {:>7.1}%  (acc proxy {acc:.1})",
            ((lat - measured) / measured).abs() * 100.0
        );
    }
    let violations = feasible
        .iter()
        .take(5)
        .filter(|(_, _, _, g)| profile(&sc, g, seed, 10).end_to_end_ms > budget_ms * 1.15)
        .count();
    println!("\nbudget violations >15% among top-5: {violations}");
}
