//! Quickstart: predict MobileNetV2's inference latency on a Pixel 4 without
//! touching the device, exactly as the paper's framework does (Section 4) —
//! and with the serving workflow this crate is built around: profile a small
//! set of synthetic NAS architectures once, train per-op predictors, freeze
//! them into a bundle file, then serve predictions from the loaded bundle
//! without ever retraining.
//!
//! Run: `cargo run --release --example quickstart`

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::predict::Method;
use edgelat::profiler::{profile, profile_set};
use edgelat::scenario::Scenario;

fn main() {
    let seed = 2022;
    // 1. Target scenario: Pixel 4 (Snapdragon 855), one large CPU core, fp32.
    let soc = edgelat::device::soc_by_name("Snapdragon855").unwrap();
    let sc = Scenario::cpu(&soc, vec![1, 0, 0], edgelat::device::DataRep::Fp32)
        .expect("1L is a valid Snapdragon855 combo");
    println!("scenario: {}", sc.id);

    // 2. One-time training-data collection: profile 60 synthetic NAS
    //    architectures on the (simulated) device.
    let train: Vec<_> =
        edgelat::nas::sample_dataset(seed, 60).into_iter().map(|a| a.graph).collect();
    println!("profiling {} synthetic architectures ...", train.len());
    let profiles = profile_set(&sc, &train, seed, 5);

    // 3. Train per-op-type GBDT latency predictors — once.
    let pred = ScenarioPredictor::train_from(
        &sc,
        &profiles,
        Method::Gbdt,
        DeductionMode::Full,
        seed,
        None,
    );
    println!("trained {} per-op models; T_overhead = {:.2} ms", pred.model_count(), pred.t_overhead_ms);

    // 4. Freeze the trained predictor into a deployable bundle file
    //    (`edgelat train --out` does the same from the CLI).
    let bundle = PredictorBundle::from_predictor(&pred).expect("native models serialize");
    let path = std::env::temp_dir().join("edgelat_quickstart_bundle.json");
    bundle.save(&path).expect("writing bundle");
    println!("serialized predictor -> {}", path.display());

    // 5. Serve: load the bundle into an owned, Send + Sync engine and
    //    predict an unseen real-world model — no device, no retraining.
    let engine = EngineBuilder::new()
        .bundle_file(&path)
        .expect("loading bundle")
        .build()
        .expect("building engine");
    let target = edgelat::zoo::by_name("mobilenetv2_wd100").unwrap();
    let resp = engine
        .predict(&PredictRequest::new(&target, sc.id.clone()))
        .expect("serving prediction");

    // 6. Compare against a "measurement" on the simulated device, and
    //    check the served prediction matches the in-memory predictor.
    let measured = profile(&sc, &target, seed, 10).end_to_end_ms;
    let in_memory = pred.predict(&target);
    assert_eq!(
        resp.e2e_ms.to_bits(),
        in_memory.to_bits(),
        "loaded bundle must reproduce the in-memory predictor exactly"
    );
    println!("\nMobileNetV2 on {}:", sc.id);
    println!("  predicted: {:8.2} ms  (served from bundle)", resp.e2e_ms);
    println!("  measured:  {measured:8.2} ms");
    println!("  error:     {:8.2} %", ((resp.e2e_ms - measured) / measured).abs() * 100.0);
}
