//! Quickstart: predict MobileNetV2's inference latency on a Pixel 4 without
//! touching the device, exactly as the paper's framework does (Section 4):
//! profile a small set of synthetic NAS architectures once, train per-op
//! predictors, then predict a new model from its model file alone.
//!
//! Run: `cargo run --release --example quickstart`

use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::predict::Method;
use edgelat::profiler::{profile, profile_set};
use edgelat::scenario::Scenario;

fn main() {
    let seed = 2022;
    // 1. Target scenario: Pixel 4 (Snapdragon 855), one large CPU core, fp32.
    let soc = edgelat::device::soc_by_name("Snapdragon855").unwrap();
    let sc = Scenario::cpu(&soc, vec![1, 0, 0], edgelat::device::DataRep::Fp32);
    println!("scenario: {}", sc.id);

    // 2. One-time training-data collection: profile 60 synthetic NAS
    //    architectures on the (simulated) device.
    let train: Vec<_> =
        edgelat::nas::sample_dataset(seed, 60).into_iter().map(|a| a.graph).collect();
    println!("profiling {} synthetic architectures ...", train.len());
    let profiles = profile_set(&sc, &train, seed, 5);

    // 3. Train per-op-type GBDT latency predictors.
    let pred = ScenarioPredictor::train_from(
        &sc,
        &profiles,
        Method::Gbdt,
        DeductionMode::Full,
        seed,
        None,
    );
    println!("trained {} per-op models; T_overhead = {:.2} ms", pred.models.len(), pred.t_overhead_ms);

    // 4. Predict an unseen real-world model — no device access needed.
    let target = edgelat::zoo::by_name("mobilenetv2_wd100").unwrap();
    let predicted = pred.predict(&target);

    // 5. Compare against a "measurement" on the simulated device.
    let measured = profile(&sc, &target, seed, 10).end_to_end_ms;
    println!("\nMobileNetV2 on {}:", sc.id);
    println!("  predicted: {predicted:8.2} ms");
    println!("  measured:  {measured:8.2} ms");
    println!("  error:     {:8.2} %", ((predicted - measured) / measured).abs() * 100.0);
}
