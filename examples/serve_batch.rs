//! Serving throughput demo: train once, serialize, load, `predict_batch`
//! over 120 NAS samples — versus the old workflow of re-profiling and
//! retraining on every `predict` invocation.
//!
//! This is the acceptance demo for the engine layer: a loaded engine must
//! serve a 100+-graph batch at least 5x faster than sequential
//! train-and-predict calls (in practice the gap is orders of magnitude,
//! which is exactly why NAS search needs the train-once/serve split).
//!
//! Run: `cargo run --release --example serve_batch`

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::predict::Method;
use edgelat::profiler::profile_set;
use edgelat::scenario::one_large_core;
use std::time::Instant;

fn main() {
    let seed = 11;
    let sc = one_large_core("Snapdragon855").expect("builtin soc");
    println!("scenario: {}", sc.id);

    // --- Train once (30 NAs, the paper's minimal-data regime) and freeze.
    let train: Vec<_> =
        edgelat::nas::sample_dataset(seed, 30).into_iter().map(|a| a.graph).collect();
    let t0 = Instant::now();
    let profiles = profile_set(&sc, &train, seed, 3);
    let pred = ScenarioPredictor::train_from(
        &sc,
        &profiles,
        Method::Gbdt,
        DeductionMode::Full,
        seed,
        None,
    );
    let train_once_s = t0.elapsed().as_secs_f64();
    let bundle = PredictorBundle::from_predictor(&pred).expect("bundle");
    let path = std::env::temp_dir().join("edgelat_serve_batch_bundle.json");
    bundle.save(&path).expect("writing bundle");
    println!("one-time profile+train: {train_once_s:.2}s -> {}", path.display());

    // --- Load and serve a 120-graph batch.
    let engine = EngineBuilder::new()
        .bundle_file(&path)
        .expect("loading bundle")
        .build()
        .expect("building engine");
    let workload: Vec<_> =
        edgelat::nas::sample_dataset(seed ^ 0x5eed, 120).into_iter().map(|a| a.graph).collect();
    let reqs: Vec<PredictRequest> =
        workload.iter().map(|g| PredictRequest::new(g, sc.id.clone())).collect();
    let t1 = Instant::now();
    let responses = engine.predict_batch(&reqs);
    let batch_s = t1.elapsed().as_secs_f64();
    let served = responses.iter().filter(|r| r.is_ok()).count();
    assert_eq!(served, workload.len(), "every request must be served");
    println!(
        "predict_batch: {} graphs in {:.4}s ({:.0} predictions/s)",
        served,
        batch_s,
        served as f64 / batch_s.max(1e-9)
    );

    // --- Second pass over the same workload: the sharded deduction memo
    // is warm, so the engine skips kernel deduction entirely.
    let t_warm = Instant::now();
    let responses_warm = engine.predict_batch(&reqs);
    let warm_s = t_warm.elapsed().as_secs_f64();
    assert_eq!(responses_warm.iter().filter(|r| r.is_ok()).count(), served);
    let stats = engine.cache_stats();
    println!(
        "warm-cache predict_batch: {:.4}s ({:.0} predictions/s); deduction memo: \
         {} hits / {} misses across {} shards",
        warm_s,
        served as f64 / warm_s.max(1e-9),
        stats.hits,
        stats.misses,
        engine.cache_shards()
    );
    assert!(
        stats.hits >= served as u64,
        "second pass must be served from the memo ({} hits)",
        stats.hits
    );

    // --- Baseline: the old retrain-per-call workflow (`edgelat predict`
    // used to re-profile and retrain on every invocation). Measure a few
    // calls and scale the per-call mean to the full batch size.
    let k = 3usize.min(workload.len());
    let t2 = Instant::now();
    for g in workload.iter().take(k) {
        let p = profile_set(&sc, &train, seed, 3);
        let fresh = ScenarioPredictor::train_from(
            &sc,
            &p,
            Method::Gbdt,
            DeductionMode::Full,
            seed,
            None,
        );
        std::hint::black_box(fresh.predict(g));
    }
    let per_call_s = t2.elapsed().as_secs_f64() / k as f64;
    let sequential_s = per_call_s * workload.len() as f64;
    println!(
        "retrain-per-call baseline: {per_call_s:.2}s/call measured over {k} calls \
         -> {sequential_s:.1}s for {} calls",
        workload.len()
    );

    let speedup = sequential_s / batch_s.max(1e-9);
    println!("\nspeedup of loaded-engine predict_batch over retrain-per-call: {speedup:.0}x");
    assert!(
        speedup >= 5.0,
        "engine serving must be at least 5x faster than retrain-per-call (got {speedup:.1}x)"
    );
    println!("OK: train-once/serve beats retrain-per-call by >=5x");
}
