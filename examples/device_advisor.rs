//! Device advisor — the paper's "insight for mobile developers" use case
//! (Section 1, contribution 3): given a model, report how to run it on each
//! SoC — best core combination, fp32 vs int8, CPU vs GPU — from predictions
//! alone, and show the counterintuitive cases (heterogeneous combos that
//! *hurt*, element-wise quantization penalties).
//!
//! Run: `cargo run --release --example device_advisor -- [model-name] [spec.json ...]`
//!
//! Any extra arguments are device-spec JSON files registered on top of the
//! builtin SoCs — the advisor then covers your device too (try
//! `examples/specs/custom_soc.json`).

use edgelat::device::DataRep;
use edgelat::profiler::profile;
use edgelat::scenario::{Registry, Scenario};

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "mobilenetv3_large_w100".into());
    let g = edgelat::zoo::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown zoo model '{name}' (see `edgelat list models`)");
        std::process::exit(2);
    });
    let mut reg = Registry::with_builtin();
    for spec_path in std::env::args().skip(2) {
        match reg.load_spec_file(&spec_path) {
            Ok(soc) => println!("registered custom device {soc} from {spec_path}"),
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
    println!(
        "advisor for {name}: {:.1}M params, {:.2} GFLOPs\n",
        g.params() as f64 / 1e6,
        g.flops() as f64 / 1e9
    );
    let seed = 42;
    for soc in reg.socs() {
        println!("=== {} ({}) ===", soc.name, soc.platform);
        let mut rows: Vec<(String, f64)> = Vec::new();
        for counts in reg.combos(&soc.name).expect("registered soc") {
            for rep in [DataRep::Fp32, DataRep::Int8] {
                let sc = Scenario::cpu(&soc, counts.clone(), rep)
                    .expect("combo from the SoC's own spec");
                let ms = profile(&sc, &g, seed, 7).end_to_end_ms;
                rows.push((format!("cpu {} {}", sc.combo_label(), rep.name()), ms));
            }
        }
        let sg = Scenario::gpu(&soc);
        rows.push(("gpu".into(), profile(&sg, &g, seed, 7).end_to_end_ms));
        rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        for (label, ms) in rows.iter().take(4) {
            println!("  {label:<24} {ms:8.2} ms");
        }
        let (wl, wm) = rows.last().map(|(l, m)| (l.clone(), *m)).unwrap();
        println!("  ... worst: {wl:<15} {wm:8.2} ms");
        // Flag the straggler effect: fastest single fast-core vs hetero combos.
        let single_fast = rows
            .iter()
            .find(|(l, _)| l.starts_with("cpu 1L") && l.ends_with("fp32"))
            .map(|(_, m)| *m);
        if let Some(sf) = single_fast {
            for (l, m) in &rows {
                if l.contains('+') && l.ends_with("fp32") && *m > sf {
                    println!("  note: {l} ({m:.2} ms) is SLOWER than 1L alone ({sf:.2} ms) — small-core straggler (Insight 1)");
                }
            }
        }
        println!();
    }
}
