//! Serve-daemon walkthrough: the whole `edgelat serve` lifecycle in one
//! process — train two scenario bundles, boot the daemon on an ephemeral
//! port, drive it from two concurrent pipelined clients (one per
//! scenario), then exercise `stats`, a hot `reload`, and a clean `drain`.
//!
//! The headline property this demo asserts is the serving contract: a
//! prediction answered over the TCP protocol is **bit-identical** to
//! calling `predict_batch` in-process on the same bundles. The daemon
//! adds micro-batching and amortized plan caching, never numerics.
//!
//! Run: `cargo run --release --example serve_daemon`

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::graph::Graph;
use edgelat::predict::Method;
use edgelat::profiler::profile_set;
use edgelat::scenario::Scenario;
use edgelat::serve::{loadgen, protocol, BundleFleet, ServeConfig, Server};
use edgelat::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn main() {
    let seed = 23;
    // --- Train one bundle per scenario into a fleet directory. This is
    // what `edgelat train --out fleet/cpu.json` does, minus the CLI.
    let dir = std::env::temp_dir().join(format!("edgelat_serve_daemon_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir fleet dir");
    let train: Vec<Graph> =
        edgelat::nas::sample_dataset(seed, 10).into_iter().map(|a| a.graph).collect();
    let sc_cpu = edgelat::scenario::one_large_core("Snapdragon855").expect("builtin soc");
    let sc_gpu = Scenario::gpu(&edgelat::device::soc_by_name("Snapdragon855").expect("soc"));
    for (sc, method, file) in
        [(&sc_cpu, Method::Gbdt, "cpu.json"), (&sc_gpu, Method::Lasso, "gpu.json")]
    {
        let profiles = profile_set(sc, &train, seed, 2);
        let pred =
            ScenarioPredictor::train_from(sc, &profiles, method, DeductionMode::Full, seed, None);
        PredictorBundle::from_predictor(&pred)
            .expect("bundle")
            .save(dir.join(file))
            .expect("writing bundle");
        println!("trained {} for {} -> {}", method.name(), sc.id, file);
    }

    // --- Ground truth: a direct engine over the same bundle files.
    let reference = EngineBuilder::new()
        .bundle_file(dir.join("cpu.json"))
        .expect("cpu bundle")
        .bundle_file(dir.join("gpu.json"))
        .expect("gpu bundle")
        .build()
        .expect("reference engine");

    // --- Boot the daemon on an ephemeral port (port 0 -> read it back).
    let fleet = BundleFleet::load(&dir, None).expect("fleet");
    let cfg = ServeConfig {
        max_batch: 16,
        max_wait: Duration::from_micros(500),
        ..ServeConfig::default()
    };
    let srv = Server::bind("127.0.0.1:0".parse().unwrap(), cfg, fleet).expect("bind");
    let addr = srv.addr();
    println!("\ndaemon listening on {addr}, serving {:?}", srv.scenario_ids());
    let daemon = std::thread::spawn(move || srv.run());

    // --- Two concurrent clients, one per scenario, each pipelining 12
    // predictions on one connection. Replies come back strictly in
    // request order, so each client just reads them sequentially.
    let workload: Vec<Graph> =
        edgelat::nas::sample_dataset(seed ^ 0x5eed, 6).into_iter().map(|a| a.graph).collect();
    std::thread::scope(|scope| {
        for sc_id in [sc_cpu.id.clone(), sc_gpu.id.clone()] {
            let (workload, reference) = (&workload, &reference);
            scope.spawn(move || {
                let mut sock = TcpStream::connect(addr).expect("connect");
                sock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                let mut rd = BufReader::new(sock.try_clone().unwrap());
                for k in 0..12usize {
                    let g = &workload[k % workload.len()];
                    let line = protocol::predict_line(&sc_id, g, Some(k as u64), None, false);
                    sock.write_all(line.as_bytes()).unwrap();
                    sock.write_all(b"\n").unwrap();
                }
                sock.flush().unwrap();
                for k in 0..12usize {
                    let g = &workload[k % workload.len()];
                    let mut line = String::new();
                    rd.read_line(&mut line).expect("reply");
                    let j = Json::parse(line.trim()).expect("reply json");
                    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{}", j.to_string());
                    let served = j.req_f64("e2e_ms").unwrap();
                    let direct = reference
                        .predict(&PredictRequest::new(g, sc_id.clone()))
                        .expect("direct predict")
                        .e2e_ms;
                    assert_eq!(
                        served.to_bits(),
                        direct.to_bits(),
                        "daemon must be bit-identical to predict_batch"
                    );
                    if k == 0 {
                        println!("{sc_id}: first reply {served:.3} ms (== direct engine)");
                    }
                }
            });
        }
    });
    println!("24 pipelined predictions across 2 scenarios: all bit-identical");

    // --- stats: counters, coalescing histogram, plan-cache hit rate.
    let stats = loadgen::request_stats(addr).expect("stats");
    let requests = stats.req("requests").unwrap();
    let batches = stats.req("batches").unwrap();
    println!(
        "stats: {} predicts in {} batches (mean {:.2}), plan-cache hit rate {:.2}",
        requests.req_f64("predict").unwrap(),
        batches.req_f64("count").unwrap(),
        batches.req_f64("mean").unwrap(),
        stats.req("plan_cache").unwrap().req_f64("hit_rate").unwrap(),
    );

    // --- Hot reload: re-read the bundle directory and swap the engine.
    // In-flight work keeps its generation; same files -> same numbers.
    let reply = loadgen::request_reload(addr).expect("reload");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
    println!(
        "reload: generation {} with {} bundles",
        reply.req_f64("generation").unwrap(),
        reply.req_f64("bundles").unwrap()
    );

    // --- Drain: stop accepting, answer everything queued, exit cleanly.
    let reply = loadgen::request_drain(addr).expect("drain");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
    let summary = daemon.join().expect("daemon thread").expect("clean drain");
    assert_eq!(summary.served_ok, 24, "every prediction answered");
    assert_eq!(summary.reloads, 1);
    println!(
        "drained: {} served ok, {} batches (mean {:.2}) over {:.2}s uptime",
        summary.served_ok, summary.batches, summary.mean_batch, summary.uptime_s
    );
    let _ = std::fs::remove_dir_all(&dir);
    println!("OK: serve daemon is bit-identical, hot-reloadable, and drains cleanly");
}
